//! Fleet-aware policy selection: Algorithm 2's EG learner run *inside*
//! the contended fleet. The paper's selector scores every candidate
//! policy against a private market, so its learned "best policy" can be
//! wrong the moment capacity is shared — a spot-greedy policy that
//! dominates in isolation can starve behind higher-priority tenants.
//!
//! [`FleetContendedEvaluator`] closes that gap: each selection round it
//! simulates the fleet **once** with the incumbent policy in the
//! learner's slot ([`FleetEngine::run_recorded`]), then swaps each
//! candidate into that slot while every other job replays its committed
//! choices — by default through the delta-replay engine
//! ([`crate::fleet::replay::ReplayPlan`]), which compacts the recorded
//! background once and charges each candidate only for the slots where
//! it actually diverges from the incumbent (full
//! [`FleetEngine::run_with_override`] re-simulation remains available as
//! the bit-identical reference path) — fanning the M counterfactual
//! evaluations across threads with
//! [`crate::fleet::sweep::run_parallel`]. The EG learner itself — the
//! job stream, weights, regret accounting — is untouched: both
//! evaluators plug into the same
//! [`crate::sched::selector::run_selection_eval`] loop, so isolated and
//! contention-aware selection trajectories are directly comparable.
//!
//! Degenerate invariant: with no background jobs and one region, every
//! counterfactual is a 1-job/1-region fleet, which reproduces
//! `run_episode` bit-for-bit — so fleet-aware selection with an empty
//! fleet yields *exactly* the isolated selection trajectory (enforced in
//! `tests/fleet_integration.rs`).

use crate::fleet::capacity::Tier;
use crate::fleet::engine::{FleetEngine, FleetJobSpec};
use crate::fleet::region::{MigrationMode, MigrationModel, Region, RegionSet};
use crate::fleet::replay::ReplayPlan;
use crate::fleet::sweep::{fleet_roster, run_parallel};
use crate::forecast::noise::NoiseSpec;
use crate::market::generator::TraceGenerator;
use crate::market::trace::SpotTrace;
use crate::obs::{Counter, Event, Recorder};
use crate::sched::job::{Job, JobGenerator};
use crate::sched::policy::Models;
use crate::sched::pool::{dedupe_specs, PolicyEnv, PolicySpec, PredictorKind};
use crate::sched::selector::{
    run_selection_eval, run_selection_eval_observed, EpisodeEvaluator,
    SelectionConfig, SelectionOutcome,
};
use crate::util::rng::Rng;
use crate::util::stats::argmax_total;

/// Scores each candidate policy by its utility inside a contended
/// multi-job, multi-region fleet rather than on a private market.
///
/// The learner's job (the one the selection loop samples each round) is
/// homed in region 0, whose market is exactly the trace the loop hands
/// over; regions 1.. get independent per-round traces seeded off the
/// round's environment seed. The `background` jobs are the rest of the
/// fleet — their policies are fixed ("committed"), and within a round
/// their per-slot choices are recorded once and replayed under every
/// candidate, so all M candidates are judged against the *same* fleet
/// behavior (the full-information EG setting Theorem 2 assumes).
#[derive(Debug, Clone)]
pub struct FleetContendedEvaluator {
    /// The committed fleet the learner contends with. Home regions must
    /// be `< n_regions`.
    pub background: Vec<FleetJobSpec>,
    /// Regions in the fleet; region 0 is the learner's.
    pub n_regions: usize,
    /// Generator for regions 1.. (fresh per-round traces).
    pub region_gen: TraceGenerator,
    pub migration: MigrationModel,
    pub migration_patience: usize,
    /// Reactive (starvation) or predictive (policy-intent) migration in
    /// the evaluation fleet — region-aware candidates plan their own
    /// moves under [`MigrationMode::Policy`].
    pub migration_mode: MigrationMode,
    /// Priority tier of the learner's job.
    pub learner_tier: Tier,
    /// Threads for fanning the per-round counterfactual fleet runs.
    pub threads: usize,
    /// Share one per-slot forecast cache across a round's M
    /// counterfactual fleet runs when the learner uses honest ARIMA
    /// predictions (bit-identical results; off = per-candidate fits).
    pub shared_forecasts: bool,
    /// Evaluate counterfactuals with the delta-replay engine
    /// ([`ReplayPlan`]) instead of full `run_with_override` fleet
    /// re-simulations. Both paths return bit-identical `FleetResult`s
    /// (enforced in `tests/fleet_properties.rs`); delta is the default
    /// because a 112-candidate round costs a fraction of M full replays.
    pub delta_replay: bool,
    /// Collapse duplicate candidate specs (clamped parameter grids can
    /// collide) and share one counterfactual across them. Utilities are
    /// deterministic, so duplicates would score identically anyway —
    /// the EG trajectory is unchanged (guarded in tests).
    pub dedupe: bool,
    /// Candidate run in the learner's slot during the recorded run:
    /// starts at index 0, then tracks each round's best candidate
    /// (lowest index on ties).
    incumbent: usize,
    /// Tracing handle, threaded into each round's fleet engine and the
    /// per-candidate replay verdicts. Disabled by default.
    obs: Recorder,
}

impl FleetContendedEvaluator {
    /// Evaluator over an explicit committed fleet (scripted scenarios).
    pub fn new(background: Vec<FleetJobSpec>, n_regions: usize) -> Self {
        assert!(n_regions >= 1);
        for s in &background {
            assert!(
                s.home_region < n_regions,
                "background job homed in region {} of {n_regions}",
                s.home_region
            );
        }
        FleetContendedEvaluator {
            background,
            n_regions,
            region_gen: TraceGenerator::calibrated(),
            migration: MigrationModel::default(),
            migration_patience: 2,
            migration_mode: MigrationMode::default(),
            learner_tier: Tier::Normal,
            threads: 1,
            shared_forecasts: true,
            delta_replay: true,
            dedupe: true,
            incumbent: 0,
            obs: Recorder::disabled(),
        }
    }

    /// A synthetic committed fleet: `n_background` jobs sampled from the
    /// default [`JobGenerator`], policies cycling through
    /// [`fleet_roster`], tiers and home regions cycling — the same mix
    /// [`crate::fleet::sweep::FleetScenario`] fields.
    pub fn synthetic(n_background: usize, n_regions: usize, seed: u64) -> Self {
        const BG_STREAM: u64 = 0x5EED_0B06_5EED_0B06;
        let gen = JobGenerator::default();
        let roster = fleet_roster();
        let mut rng = Rng::new(seed ^ BG_STREAM);
        let background = (0..n_background)
            .map(|k| {
                let job = gen.sample(&mut rng);
                FleetJobSpec {
                    job,
                    policy: roster[k % roster.len()],
                    predictor: PredictorKind::Noisy(
                        NoiseSpec::fixed_mag_uniform(0.1),
                    ),
                    seed: seed
                        ^ BG_STREAM
                        ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9),
                    tier: Tier::cycle(k),
                    home_region: k % n_regions,
                    arrival: 0,
                }
            })
            .collect();
        FleetContendedEvaluator::new(background, n_regions)
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_learner_tier(mut self, tier: Tier) -> Self {
        self.learner_tier = tier;
        self
    }

    pub fn with_migration(mut self, m: MigrationModel) -> Self {
        self.migration = m;
        self
    }

    pub fn with_migration_patience(mut self, patience: usize) -> Self {
        self.migration_patience = patience;
        self
    }

    pub fn with_migration_mode(mut self, mode: MigrationMode) -> Self {
        self.migration_mode = mode;
        self
    }

    /// Evaluate every counterfactual with full `run_with_override` fleet
    /// re-simulations — the reference path delta replay is tested
    /// against (and the baseline the `perf_hotpaths` selection-round
    /// bench measures it over).
    pub fn with_full_replay(mut self) -> Self {
        self.delta_replay = false;
        self
    }

    /// Toggle candidate deduplication (on by default).
    pub fn with_dedupe(mut self, on: bool) -> Self {
        self.dedupe = on;
        self
    }

    /// Attach a tracing recorder: each round's recorded fleet run emits
    /// arbitration/preemption/migration events, and every distinct
    /// candidate's delta replay emits a `replay` verdict (how many slots
    /// were clean, replayed, or adopted from the fork trie). Utilities
    /// are unchanged bit-for-bit — the recorder only reads results.
    pub fn with_recorder(mut self, obs: Recorder) -> Self {
        self.obs = obs;
        self
    }

    /// Index of the candidate currently run in the learner's slot
    /// during recorded runs.
    pub fn incumbent(&self) -> usize {
        self.incumbent
    }

    /// Materialize this round's fleet: region 0 carries the learner's
    /// trace, regions 1.. get generated traces seeded off `round_seed`.
    fn build_engine(
        &self,
        models: &Models,
        trace: &SpotTrace,
        round_seed: u64,
    ) -> FleetEngine {
        let mut regions = Vec::with_capacity(self.n_regions);
        regions.push(Region { name: "learner".to_string(), trace: trace.clone() });
        for r in 1..self.n_regions {
            regions.push(Region {
                name: format!("bg-{r}"),
                trace: self.region_gen.generate(
                    round_seed ^ (r as u64).wrapping_mul(0xA5A5_5A5A_9E37_79B9),
                ),
            });
        }
        let engine = FleetEngine::new(
            *models,
            RegionSet::new(regions).with_migration(self.migration),
        )
        .with_migration_patience(self.migration_patience)
        .with_migration_mode(self.migration_mode)
        .with_recorder(self.obs.clone());
        if self.shared_forecasts {
            engine
        } else {
            engine.without_shared_forecasts()
        }
    }
}

impl EpisodeEvaluator for FleetContendedEvaluator {
    fn utilities(
        &mut self,
        specs: &[PolicySpec],
        job: &Job,
        trace: &SpotTrace,
        models: &Models,
        env: &PolicyEnv,
    ) -> Vec<f64> {
        let engine = self.build_engine(models, trace, env.seed);
        let incumbent = self.incumbent.min(specs.len() - 1);
        let mut all = self.background.clone();
        let learner_idx = all.len();
        all.push(FleetJobSpec {
            job: *job,
            policy: specs[incumbent],
            predictor: env.predictor.clone(),
            seed: env.seed,
            tier: self.learner_tier,
            home_region: 0,
            arrival: 0,
        });

        // One live fleet simulation, then counterfactuals for every
        // *distinct* candidate: overriding with the incumbent itself
        // reproduces the recorded run bit-for-bit (the identity enforced
        // in engine and integration tests), so its utility is read
        // straight off the recorded result; duplicate specs (clamped
        // parameters can collide) share one evaluation; and each
        // remaining candidate is scored by the delta-replay engine,
        // which compacts the recorded background once and then pays only
        // for how much the candidate diverges from it.
        let committed = engine.run_recorded(&all);
        let (uniq, back) = if self.dedupe {
            dedupe_specs(specs)
        } else {
            (specs.to_vec(), (0..specs.len()).collect())
        };
        let incumbent_u = back[incumbent];
        let plan = self
            .delta_replay
            .then(|| ReplayPlan::new(&engine, &all, &committed, learner_idx));
        let obs = &self.obs;
        let uu: Vec<f64> = run_parallel(&uniq, self.threads, |i, cand| {
            let utility = if i == incumbent_u {
                committed.result.jobs[learner_idx].episode.utility
            } else if let Some(plan) = &plan {
                if obs.is_enabled() {
                    // Replay verdict per distinct candidate: events are
                    // keyed by `i`, which exactly one worker owns, so
                    // the merged trace is thread-count invariant.
                    let (r, st) = plan.counterfactual_stats(*cand);
                    obs.add(Counter::CleanSlots, st.clean_slots as u64);
                    obs.add(Counter::ReplayedSlots, st.replayed_slots as u64);
                    obs.add(Counter::AdoptedSlots, st.adopted_slots as u64);
                    obs.emit(|| Event::Replay {
                        round: obs.round(),
                        candidate: i,
                        label: cand.label(),
                        clean_slots: st.clean_slots,
                        replayed_slots: st.replayed_slots,
                        adopted_slots: st.adopted_slots,
                        diverged_at: st.diverged_at,
                    });
                    r.jobs[learner_idx].episode.utility
                } else {
                    plan.counterfactual(*cand).jobs[learner_idx].episode.utility
                }
            } else {
                engine
                    .run_with_override(
                        &all,
                        &committed.traces,
                        learner_idx,
                        *cand,
                    )
                    .jobs[learner_idx]
                    .episode
                    .utility
            };
            job.normalize_utility(utility, models.on_demand_price)
        });
        if let Some(plan) = &plan {
            if self.obs.is_enabled() {
                let (hits, misses) = plan.fork_stats();
                self.obs.emit(|| Event::ReplayCache {
                    round: self.obs.round(),
                    hits,
                    misses,
                });
            }
        }
        let u: Vec<f64> = back.iter().map(|&i| uu[i]).collect();
        self.incumbent = argmax_total(&u);
        u
    }
}

/// Algorithm 2 learning *under contention*: the standard selection loop
/// with the counterfactual pool evaluated inside `evaluator`'s fleet.
/// Deterministic for a fixed evaluator configuration — the trajectory is
/// bit-identical for any `threads` (the counterfactual fan-out preserves
/// input order).
pub fn run_fleet_selection(
    specs: &[PolicySpec],
    jobs: &JobGenerator,
    models: &Models,
    trace_gen: &TraceGenerator,
    predictor_at: impl FnMut(usize) -> PredictorKind,
    cfg: &SelectionConfig,
    evaluator: &mut FleetContendedEvaluator,
) -> SelectionOutcome {
    run_selection_eval(specs, jobs, models, trace_gen, predictor_at, cfg, evaluator)
}

/// [`run_fleet_selection`] with a live [`Recorder`]: the selection loop
/// writes the per-round ledger through `obs`, and the evaluator's replay
/// verdicts, arbitration, and migration events land in the same log.
///
/// The recorder is cloned onto the evaluator (replacing any recorder it
/// already carries), so callers only wire one handle. Tracing never
/// perturbs the outcome: the trajectory stays bit-identical to
/// [`run_fleet_selection`] for the same inputs.
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_selection_observed(
    specs: &[PolicySpec],
    jobs: &JobGenerator,
    models: &Models,
    trace_gen: &TraceGenerator,
    predictor_at: impl FnMut(usize) -> PredictorKind,
    cfg: &SelectionConfig,
    evaluator: &mut FleetContendedEvaluator,
    obs: &Recorder,
) -> SelectionOutcome {
    evaluator.obs = obs.clone();
    run_selection_eval_observed(
        specs,
        jobs,
        models,
        trace_gen,
        predictor_at,
        cfg,
        evaluator,
        obs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_pool() -> Vec<PolicySpec> {
        vec![
            PolicySpec::OdOnly,
            PolicySpec::Msu,
            PolicySpec::UniformProgress,
            PolicySpec::Ahanp { sigma: 0.5 },
        ]
    }

    #[test]
    fn empty_fleet_matches_isolated_selection_exactly() {
        // No background, one region: every counterfactual is a
        // 1-job/1-region fleet == run_episode, so the whole trajectory
        // must equal the isolated selector's bit-for-bit.
        use crate::sched::selector::run_selection;
        let specs = small_pool();
        let jobs = JobGenerator::default();
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let cfg = SelectionConfig { k_jobs: 15, seed: 21, snapshot_every: 5 };
        let noise =
            |_: usize| PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1));

        let isolated = run_selection(&specs, &jobs, &models, &gen, noise, &cfg);
        let mut ev = FleetContendedEvaluator::new(Vec::new(), 1);
        let fleet =
            run_fleet_selection(&specs, &jobs, &models, &gen, noise, &cfg, &mut ev);

        assert_eq!(isolated.realized, fleet.realized);
        assert_eq!(isolated.expected, fleet.expected);
        assert_eq!(isolated.regret, fleet.regret);
        assert_eq!(isolated.final_weights, fleet.final_weights);
        assert_eq!(isolated.snapshots, fleet.snapshots);
        assert_eq!(isolated.converged_to, fleet.converged_to);
        assert_eq!(isolated.best_fixed, fleet.best_fixed);
    }

    #[test]
    fn synthetic_evaluator_is_deterministic_and_normalized() {
        let specs = small_pool();
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let job = Job::paper_reference();
        let trace = gen.generate(9).slice_from(40);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            77,
        );
        let mut a = FleetContendedEvaluator::synthetic(5, 2, 3);
        let mut b = FleetContendedEvaluator::synthetic(5, 2, 3);
        let ua = a.utilities(&specs, &job, &trace, &models, &env);
        let ub = b.utilities(&specs, &job, &trace, &models, &env);
        assert_eq!(ua, ub);
        assert_eq!(ua.len(), specs.len());
        assert!(ua.iter().all(|u| (0.0..=1.0).contains(u)));
        assert_eq!(a.incumbent(), b.incumbent());
    }

    #[test]
    fn delta_and_full_replay_utilities_are_bit_identical() {
        let specs = small_pool();
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let job = Job::paper_reference();
        let trace = gen.generate(14).slice_from(35);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            23,
        );
        let mut delta = FleetContendedEvaluator::synthetic(6, 2, 9);
        let mut full = FleetContendedEvaluator::synthetic(6, 2, 9).with_full_replay();
        let ud = delta.utilities(&specs, &job, &trace, &models, &env);
        let uf = full.utilities(&specs, &job, &trace, &models, &env);
        assert_eq!(ud, uf);
        assert_eq!(delta.incumbent(), full.incumbent());
    }

    #[test]
    fn policy_mode_delta_and_full_replay_agree() {
        // The delta/full bit-identity must survive policy-driven
        // migration: region-aware candidates emit intents inside the
        // counterfactuals, and both engines must score them identically.
        let mut specs = small_pool();
        specs.push(PolicySpec::Ahap { omega: 4, v: 2, sigma: 0.7 });
        specs.push(PolicySpec::Ahap { omega: 2, v: 1, sigma: 0.9 });
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let job = Job::paper_reference();
        let trace = gen.generate(18).slice_from(30);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            41,
        );
        let mut delta = FleetContendedEvaluator::synthetic(5, 3, 15)
            .with_migration_mode(MigrationMode::Policy)
            .with_threads(3);
        let mut full = FleetContendedEvaluator::synthetic(5, 3, 15)
            .with_migration_mode(MigrationMode::Policy)
            .with_full_replay();
        let ud = delta.utilities(&specs, &job, &trace, &models, &env);
        let uf = full.utilities(&specs, &job, &trace, &models, &env);
        assert_eq!(ud, uf);
        assert_eq!(delta.incumbent(), full.incumbent());
    }

    #[test]
    fn duplicate_candidates_share_their_counterfactual() {
        // A pool with collisions (as clamped parameter grids produce):
        // dedupe must hand duplicates the identical utility and leave
        // the argmax on the first occurrence, exactly as evaluating
        // every copy would.
        let mut specs = small_pool();
        specs.push(PolicySpec::Msu); // duplicate of index 1
        specs.push(PolicySpec::Ahanp { sigma: 0.5 }); // duplicate of index 3
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let job = Job::paper_reference();
        let trace = gen.generate(4).slice_from(25);
        let env = PolicyEnv::new(PredictorKind::Oracle, trace.clone(), 3);

        let mut deduped = FleetContendedEvaluator::synthetic(4, 2, 7);
        let mut plain =
            FleetContendedEvaluator::synthetic(4, 2, 7).with_dedupe(false);
        let ud = deduped.utilities(&specs, &job, &trace, &models, &env);
        let up = plain.utilities(&specs, &job, &trace, &models, &env);
        assert_eq!(ud, up, "dedupe changed the utility vector");
        assert_eq!(ud[1], ud[4]);
        assert_eq!(ud[3], ud[5]);
        assert_eq!(deduped.incumbent(), plain.incumbent());
    }

    #[test]
    fn traced_utilities_are_bit_identical_and_emit_replay_verdicts() {
        // A live recorder on the evaluator must not move a single bit of
        // the utility vector, and the trace must carry one replay verdict
        // per distinct non-incumbent candidate plus the fork-cache line.
        let specs = small_pool();
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let job = Job::paper_reference();
        let trace = gen.generate(14).slice_from(35);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            23,
        );
        let mut plain = FleetContendedEvaluator::synthetic(6, 2, 9);
        let obs = Recorder::enabled();
        let mut traced =
            FleetContendedEvaluator::synthetic(6, 2, 9).with_recorder(obs.clone());
        let up = plain.utilities(&specs, &job, &trace, &models, &env);
        let ut = traced.utilities(&specs, &job, &trace, &models, &env);
        assert_eq!(up, ut, "tracing perturbed the utility vector");
        assert_eq!(plain.incumbent(), traced.incumbent());

        let log = obs.finish().expect("enabled recorder yields a log");
        let kinds = log.kind_counts();
        let replays =
            kinds.iter().find(|(k, _)| k == "replay").map(|(_, n)| *n);
        // The incumbent short-circuits, every other distinct candidate
        // gets a verdict.
        assert_eq!(replays, Some(specs.len() - 1));
        assert!(kinds.iter().any(|(k, _)| *k == "replay_cache"));
    }

    #[test]
    fn incumbent_tracks_round_best() {
        let specs = small_pool();
        let models = Models::paper_default();
        let gen = TraceGenerator::calibrated();
        let job = Job::paper_reference();
        let trace = gen.generate(2).slice_from(30);
        let env = PolicyEnv::new(PredictorKind::Oracle, trace.clone(), 5);
        let mut ev = FleetContendedEvaluator::synthetic(3, 2, 11);
        assert_eq!(ev.incumbent(), 0);
        let u = ev.utilities(&specs, &job, &trace, &models, &env);
        assert_eq!(ev.incumbent(), crate::util::stats::argmax_total(&u));
    }
}
