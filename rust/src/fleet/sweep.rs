//! Parallel sweep engine: fan policy × trace × fleet evaluations across
//! cores with `std::thread::scope` (no external thread-pool crate). Work
//! is pulled off a shared atomic counter, so long tasks don't straggle a
//! static partition; results come back in input order, making parallel
//! runs bit-identical to sequential ones.
//!
//! The benches (`fig11_fleet_scaling`), the policy selector's
//! counterfactual evaluation ([`run_selection_parallel`]), and the
//! fleet-aware selector's per-round counterfactual fleet runs
//! ([`crate::fleet::select::FleetContendedEvaluator`]) all route through
//! [`run_parallel`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::fleet::capacity::Tier;
use crate::fleet::engine::{FleetEngine, FleetJobSpec, FleetResult};
use crate::fleet::region::{MigrationMode, MigrationModel, RegionSet};
use crate::forecast::noise::NoiseSpec;
use crate::market::generator::{GeneratorConfig, TraceGenerator};
use crate::market::trace::SpotTrace;
use crate::obs::Recorder;
use crate::sched::ahap::SolverKind;
use crate::sched::job::{Job, JobGenerator};
use crate::sched::policy::Models;
use crate::sched::pool::{
    dedupe_specs, PolicyEnv, PolicySpec, PolicyWorkspace, PredictorKind,
};
use crate::sched::selector::{
    run_selection_eval_observed, run_selection_with, SelectionConfig,
    SelectionOutcome,
};
use crate::sched::simulate::run_episode;
use crate::util::rng::Rng;

/// Threads the host can usefully run.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on `threads` OS threads (work-stealing via an
/// atomic cursor). Returns results in input order; with `threads <= 1`
/// this degrades to a plain sequential map, and for any thread count the
/// output is identical to the sequential one (tasks are independent).
pub fn run_parallel<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let mut states = vec![(); threads];
    run_parallel_with(items, &mut states, |_, i, it| f(i, it))
}

/// [`run_parallel`] with one mutable worker state per thread
/// (`states.len()` = worker count): each spawned worker owns exactly one
/// `&mut S` for its whole lifetime, so callers can keep scratch buffers
/// or warm policy instances (see
/// [`crate::sched::pool::PolicyWorkspace`]) alive across the items a
/// worker processes — and, by holding the state vector across calls,
/// across episodes too. Results come back in input order and must not
/// depend on which worker computed them (states are caches, not inputs);
/// every caller here upholds that, which is what keeps parallel runs
/// bit-identical to sequential ones.
pub fn run_parallel_with<T, S, R, F>(items: &[T], states: &mut [S], f: F) -> Vec<R>
where
    T: Sync,
    S: Send,
    R: Send,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    assert!(!states.is_empty(), "need at least one worker state");
    if states.len() == 1 || n == 1 {
        let st = &mut states[0];
        return items.iter().enumerate().map(|(i, it)| f(st, i, it)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        let cursor = &cursor;
        let done = &done;
        let f = &f;
        for st in states.iter_mut() {
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(st, i, &items[i]);
                done.lock().unwrap().push((i, r));
            });
        }
    });
    let mut out = done.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Counterfactual utilities of a whole policy pool on one job/trace,
/// normalized for the EG selector — the selector's inner loop, fanned
/// across cores. Episodes are independent and deterministic, so the
/// result equals the sequential evaluation exactly.
pub fn counterfactual_utilities(
    specs: &[PolicySpec],
    job: &Job,
    trace: &SpotTrace,
    models: &Models,
    env: &PolicyEnv,
    threads: usize,
) -> Vec<f64> {
    let threads = threads.max(1).min(specs.len().max(1));
    let mut workspaces: Vec<PolicyWorkspace> =
        (0..threads).map(|_| PolicyWorkspace::new()).collect();
    counterfactual_utilities_in(specs, job, trace, models, env, &mut workspaces, 0)
}

/// [`counterfactual_utilities`] against caller-owned per-worker
/// [`PolicyWorkspace`]s: duplicate specs are collapsed up front (the
/// utility is a deterministic function of the spec, so duplicates share
/// one episode), and each worker re-targets its cached AHAP instance
/// per candidate instead of rebuilding policy + predictor 112 times a
/// round. `epoch` must change per round so stale predictors are dropped.
/// Bit-identical to per-spec fresh builds, for any worker count.
pub fn counterfactual_utilities_in(
    specs: &[PolicySpec],
    job: &Job,
    trace: &SpotTrace,
    models: &Models,
    env: &PolicyEnv,
    workspaces: &mut [PolicyWorkspace],
    epoch: u64,
) -> Vec<f64> {
    let (uniq, back) = dedupe_specs(specs);
    let uu = run_parallel_with(&uniq, workspaces, |ws, _, spec| {
        let policy = ws.policy_for(spec, env, epoch);
        let r = run_episode(job, trace, models, policy);
        job.normalize_utility(r.utility, models.on_demand_price)
    });
    back.into_iter().map(|i| uu[i]).collect()
}

/// Algorithm 2 with the per-job counterfactual pool evaluation (112
/// episodes per job) fanned across `threads` cores, worker policy
/// instances reused across rounds. Produces exactly the same
/// [`SelectionOutcome`] as [`crate::sched::selector::run_selection`] —
/// only faster.
pub fn run_selection_parallel(
    specs: &[PolicySpec],
    jobs: &JobGenerator,
    models: &Models,
    trace_gen: &TraceGenerator,
    predictor_at: impl FnMut(usize) -> PredictorKind,
    cfg: &SelectionConfig,
    threads: usize,
) -> SelectionOutcome {
    let workers = threads.max(1).min(specs.len().max(1));
    let mut workspaces: Vec<PolicyWorkspace> =
        (0..workers).map(|_| PolicyWorkspace::new()).collect();
    let mut epoch = 0u64;
    run_selection_with(
        specs,
        jobs,
        models,
        trace_gen,
        predictor_at,
        cfg,
        |specs, job, trace, models, env| {
            epoch += 1;
            counterfactual_utilities_in(
                specs,
                job,
                trace,
                models,
                env,
                &mut workspaces,
                epoch,
            )
        },
    )
}

/// [`run_selection_parallel`] with a live [`Recorder`]: identical
/// trajectory (the per-round ledger is written from values the loop
/// already computes), plus ledger + counter events in the log.
#[allow(clippy::too_many_arguments)]
pub fn run_selection_parallel_observed(
    specs: &[PolicySpec],
    jobs: &JobGenerator,
    models: &Models,
    trace_gen: &TraceGenerator,
    predictor_at: impl FnMut(usize) -> PredictorKind,
    cfg: &SelectionConfig,
    threads: usize,
    obs: &Recorder,
) -> SelectionOutcome {
    let workers = threads.max(1).min(specs.len().max(1));
    let mut workspaces: Vec<PolicyWorkspace> =
        (0..workers).map(|_| PolicyWorkspace::new()).collect();
    let mut epoch = 0u64;
    let mut eval = |specs: &[PolicySpec],
                    job: &Job,
                    trace: &SpotTrace,
                    models: &Models,
                    env: &PolicyEnv| {
        epoch += 1;
        counterfactual_utilities_in(
            specs,
            job,
            trace,
            models,
            env,
            &mut workspaces,
            epoch,
        )
    };
    run_selection_eval_observed(
        specs, jobs, models, trace_gen, predictor_at, cfg, &mut eval, obs,
    )
}

/// A self-contained fleet experiment: how many jobs across how many
/// regions, under which market/job/noise calibration. The unit of work
/// for [`run_fleet_sweep`].
#[derive(Debug, Clone)]
pub struct FleetScenario {
    pub n_jobs: usize,
    pub n_regions: usize,
    pub seed: u64,
    pub market: GeneratorConfig,
    pub jobs: JobGenerator,
    pub models: Models,
    pub noise: NoiseSpec,
    pub migration: MigrationModel,
    pub migration_patience: usize,
    /// Reactive (starvation reflex) or predictive (policy intents)
    /// migration — see [`MigrationMode`].
    pub migration_mode: MigrationMode,
    /// Arrival spacing: job k arrives at `(k % 4) * stagger` (0 = all at
    /// slot 0).
    pub stagger: usize,
    /// Background churn: expected Poisson *arrivals per slot* of extra
    /// jobs over the base fleet's horizon (0 = the historical fixed
    /// fleet). Churn jobs depart naturally — at completion or at their
    /// (randomly sampled) deadline — so the committed background the
    /// fleet contends with is genuinely non-stationary. Sampled once at
    /// build time from a dedicated seed stream, so results are
    /// deterministic and identical across thread counts.
    pub churn: f64,
    /// Eq. 10 window-solver backend every AHAP policy in the fleet
    /// uses; the default (`Greedy`) is the historical behavior.
    pub solver: SolverKind,
}

impl FleetScenario {
    /// Paper-calibrated scenario.
    pub fn new(n_jobs: usize, n_regions: usize, seed: u64) -> Self {
        assert!(n_jobs >= 1 && n_regions >= 1);
        FleetScenario {
            n_jobs,
            n_regions,
            seed,
            market: GeneratorConfig::default(),
            jobs: JobGenerator::default(),
            models: Models::paper_default(),
            noise: NoiseSpec::fixed_mag_uniform(0.1),
            migration: MigrationModel::default(),
            migration_patience: 2,
            migration_mode: MigrationMode::default(),
            stagger: 0,
            churn: 0.0,
            solver: SolverKind::default(),
        }
    }

    pub fn with_solver(mut self, solver: SolverKind) -> Self {
        self.solver = solver;
        self
    }

    pub fn with_stagger(mut self, stagger: usize) -> Self {
        self.stagger = stagger;
        self
    }

    pub fn with_migration_mode(mut self, mode: MigrationMode) -> Self {
        self.migration_mode = mode;
        self
    }

    /// Enable background churn at `rate` expected arrivals per slot.
    pub fn with_churn(mut self, rate: f64) -> Self {
        assert!(rate >= 0.0 && rate.is_finite(), "churn rate must be finite and ≥ 0");
        self.churn = rate;
        self
    }

    /// Materialize the engine and job roster. Policies are drawn
    /// round-robin from [`fleet_roster`]; tiers and home regions cycle.
    ///
    /// The scenario seed fans out into domain-separated streams —
    /// region traces, job sampling, per-job predictor noise, and churn
    /// arrivals — so no two of them ever consume the same PRNG sequence
    /// (a shared stream would correlate a job's forecast errors with the
    /// very market it runs on and bias sweep statistics).
    pub fn build(&self) -> (FleetEngine, Vec<FleetJobSpec>) {
        const JOBS_STREAM: u64 = 0x9E37_79B9_7F4A_7C15;
        const NOISE_STREAM: u64 = 0xD1B5_4A32_D192_ED03;
        const CHURN_STREAM: u64 = 0xC0DE_C0DE_5EED_51DE;
        let gen = TraceGenerator::new(self.market.clone());
        let regions = RegionSet::generated(self.n_regions, &gen, self.seed)
            .with_migration(self.migration);
        let engine = FleetEngine::new(self.models, regions)
            .with_migration_patience(self.migration_patience)
            .with_migration_mode(self.migration_mode)
            .with_solver(self.solver);
        let roster = fleet_roster();
        let mut rng = Rng::new(self.seed ^ JOBS_STREAM);
        let mut specs: Vec<FleetJobSpec> = (0..self.n_jobs)
            .map(|k| {
                let job = self.jobs.sample(&mut rng);
                FleetJobSpec {
                    job,
                    policy: roster[k % roster.len()],
                    predictor: PredictorKind::Noisy(self.noise),
                    seed: self.seed
                        ^ NOISE_STREAM
                        ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9),
                    tier: Tier::cycle(k),
                    home_region: k % self.n_regions,
                    arrival: (k % 4) * self.stagger,
                }
            })
            .collect();

        // Seeded Poisson churn: extra background jobs arriving over the
        // base fleet's horizon (and departing at completion/deadline).
        // Sampled here, single-threaded, from its own domain-separated
        // stream — the resulting spec list is a pure function of the
        // scenario, so sweeps stay bit-identical across thread counts.
        if self.churn > 0.0 {
            let horizon = specs
                .iter()
                .map(|s| s.arrival + s.job.deadline)
                .max()
                .unwrap_or(0);
            let mut crng = Rng::new(self.seed ^ CHURN_STREAM);
            let mut k = self.n_jobs;
            for slot in 0..horizon {
                for _ in 0..crng.poisson(self.churn) {
                    let job = self.jobs.sample(&mut crng);
                    specs.push(FleetJobSpec {
                        job,
                        policy: roster[k % roster.len()],
                        predictor: PredictorKind::Noisy(self.noise),
                        seed: self.seed
                            ^ CHURN_STREAM
                            ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9),
                        tier: Tier::cycle(k),
                        home_region: k % self.n_regions,
                        arrival: slot,
                    });
                    k += 1;
                }
            }
        }
        (engine, specs)
    }

    /// Build and run.
    pub fn run(&self) -> FleetResult {
        let (engine, specs) = self.build();
        engine.run(&specs)
    }

    /// Build and run with a live [`Recorder`] attached: the engine
    /// narrates arbitration, preemption, and migration into `obs` while
    /// producing the exact same [`FleetResult`] as [`FleetScenario::run`]
    /// (tracing never perturbs the simulation — see
    /// [`crate::obs::recorder`]).
    pub fn run_traced(&self, obs: &Recorder) -> FleetResult {
        let (engine, specs) = self.build();
        engine.with_recorder(obs.clone()).run(&specs)
    }
}

/// The policy mix synthetic fleets cycle through: the three baselines,
/// a mid-grid AHANP, and three representative AHAP corners.
pub fn fleet_roster() -> Vec<PolicySpec> {
    vec![
        PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
        PolicySpec::Msu,
        PolicySpec::Ahanp { sigma: 0.5 },
        PolicySpec::UniformProgress,
        PolicySpec::Ahap { omega: 5, v: 2, sigma: 0.9 },
        PolicySpec::OdOnly,
        PolicySpec::Ahap { omega: 1, v: 1, sigma: 0.5 },
    ]
}

/// Run many scenarios across `threads` cores (the fig11 bench's outer
/// loop and the CLI's `fleet --sweeps` path).
pub fn run_fleet_sweep(
    scenarios: &[FleetScenario],
    threads: usize,
) -> Vec<FleetResult> {
    run_parallel(scenarios, threads, |_, sc| sc.run())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_parallel_preserves_order_and_values() {
        let items: Vec<usize> = (0..97).collect();
        let seq = run_parallel(&items, 1, |i, &x| i * 1000 + x * x);
        let par = run_parallel(&items, 4, |i, &x| i * 1000 + x * x);
        assert_eq!(seq, par);
        assert_eq!(seq.len(), 97);
        assert_eq!(seq[3], 3 * 1000 + 9);
    }

    #[test]
    fn run_parallel_handles_empty_and_oversubscription() {
        let empty: Vec<u32> = vec![];
        assert!(run_parallel(&empty, 8, |_, &x| x).is_empty());
        let one = [5u32];
        assert_eq!(run_parallel(&one, 64, |_, &x| x * 2), vec![10]);
    }

    #[test]
    fn run_parallel_with_reuses_one_state_per_worker() {
        // Each worker state counts the items it processed; the counts
        // must partition the input (every item handled exactly once)
        // while results stay in input order.
        let items: Vec<usize> = (0..50).collect();
        let mut states = vec![0usize; 4];
        let out = run_parallel_with(&items, &mut states, |st, i, &x| {
            *st += 1;
            i + x
        });
        assert_eq!(out, (0..50).map(|i| 2 * i).collect::<Vec<_>>());
        assert_eq!(states.iter().sum::<usize>(), 50);
        // Sequential (one state) processes everything on that state.
        let mut solo = vec![0usize];
        let seq = run_parallel_with(&items, &mut solo, |st, i, &x| {
            *st += 1;
            i + x
        });
        assert_eq!(seq, out);
        assert_eq!(solo[0], 50);
    }

    #[test]
    fn workspace_counterfactuals_match_fresh_build_episodes() {
        // The amortized path (dedupe + per-worker AHAP reuse) must be
        // bit-identical to per-spec fresh builds — including duplicates.
        let specs = vec![
            PolicySpec::Ahap { omega: 4, v: 2, sigma: 0.7 },
            PolicySpec::OdOnly,
            PolicySpec::Ahap { omega: 2, v: 1, sigma: 0.3 },
            PolicySpec::Ahap { omega: 4, v: 2, sigma: 0.7 }, // duplicate
            PolicySpec::Ahanp { sigma: 0.5 },
        ];
        let job = Job::paper_reference();
        let models = Models::paper_default();
        let trace = TraceGenerator::calibrated().generate(11).slice_from(35);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            5,
        );
        let fresh: Vec<f64> = specs
            .iter()
            .map(|s| {
                let mut p = s.build(&env);
                let r = run_episode(&job, &trace, &models, p.as_mut());
                job.normalize_utility(r.utility, models.on_demand_price)
            })
            .collect();
        for threads in [1usize, 3] {
            let got =
                counterfactual_utilities(&specs, &job, &trace, &models, &env, threads);
            assert_eq!(got, fresh, "diverged at {threads} workers");
        }
        assert_eq!(fresh[0], fresh[3], "duplicates must share the utility");
    }

    #[test]
    fn scenario_is_deterministic() {
        let sc = FleetScenario::new(6, 2, 11).with_stagger(3);
        assert_eq!(sc.run(), sc.run());
    }

    #[test]
    fn churn_adds_staggered_background_jobs_deterministically() {
        let churned = FleetScenario::new(4, 2, 19).with_churn(0.6);
        let (_, specs_a) = churned.build();
        let (_, specs_b) = churned.build();
        assert_eq!(specs_a.len(), specs_b.len(), "churn sampling must be seeded");
        let (_, base_specs) = FleetScenario::new(4, 2, 19).build();
        assert!(
            specs_a.len() > base_specs.len(),
            "rate 0.6 over a ≥10-slot horizon should add jobs ({} vs {})",
            specs_a.len(),
            base_specs.len()
        );
        // Base jobs are untouched (churn extends, never perturbs) and
        // churn arrivals land strictly inside the base horizon.
        let horizon = base_specs
            .iter()
            .map(|s| s.arrival + s.job.deadline)
            .max()
            .unwrap();
        for (a, b) in specs_a.iter().zip(&base_specs) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.policy.label(), b.policy.label());
        }
        for s in &specs_a[base_specs.len()..] {
            assert!(s.arrival < horizon);
        }
        // The churned fleet itself runs deterministically — and
        // identically across thread counts via the sweep engine.
        assert_eq!(churned.run(), churned.run());
        let scenarios = vec![churned.clone(), FleetScenario::new(3, 2, 7).with_churn(1.0)];
        assert_eq!(run_fleet_sweep(&scenarios, 1), run_fleet_sweep(&scenarios, 4));
    }

    #[test]
    fn zero_churn_is_the_historical_fleet() {
        let a = FleetScenario::new(5, 2, 13).with_stagger(2);
        let b = a.clone().with_churn(0.0);
        assert_eq!(a.run(), b.run());
    }

    #[test]
    fn sweep_parallel_equals_sequential() {
        let scenarios: Vec<FleetScenario> =
            (0..4).map(|s| FleetScenario::new(4, 2, s)).collect();
        let seq = run_fleet_sweep(&scenarios, 1);
        let par = run_fleet_sweep(&scenarios, 4);
        assert_eq!(seq, par);
    }

    #[test]
    fn counterfactuals_match_sequential_episodes() {
        let specs = vec![
            PolicySpec::OdOnly,
            PolicySpec::Msu,
            PolicySpec::UniformProgress,
            PolicySpec::Ahanp { sigma: 0.5 },
        ];
        let job = Job::paper_reference();
        let models = Models::paper_default();
        let trace = TraceGenerator::calibrated().generate(3).slice_from(40);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            9,
        );
        let par =
            counterfactual_utilities(&specs, &job, &trace, &models, &env, 4);
        let seq: Vec<f64> = specs
            .iter()
            .map(|s| {
                let mut p = s.build(&env);
                let r = run_episode(&job, &trace, &models, p.as_mut());
                job.normalize_utility(r.utility, models.on_demand_price)
            })
            .collect();
        assert_eq!(par, seq);
    }
}
