//! Parallel sweep engine: fan policy × trace × fleet evaluations across
//! cores with `std::thread::scope` (no external thread-pool crate). Work
//! is pulled off a shared atomic counter, so long tasks don't straggle a
//! static partition; results come back in input order, making parallel
//! runs bit-identical to sequential ones.
//!
//! The benches (`fig11_fleet_scaling`), the policy selector's
//! counterfactual evaluation ([`run_selection_parallel`]), and the
//! fleet-aware selector's per-round counterfactual fleet runs
//! ([`crate::fleet::select::FleetContendedEvaluator`]) all route through
//! [`run_parallel`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::fleet::capacity::Tier;
use crate::fleet::engine::{FleetEngine, FleetJobSpec, FleetResult};
use crate::fleet::region::{MigrationModel, RegionSet};
use crate::forecast::noise::NoiseSpec;
use crate::market::generator::{GeneratorConfig, TraceGenerator};
use crate::market::trace::SpotTrace;
use crate::sched::job::{Job, JobGenerator};
use crate::sched::policy::Models;
use crate::sched::pool::{PolicyEnv, PolicySpec, PredictorKind};
use crate::sched::selector::{
    run_selection_with, SelectionConfig, SelectionOutcome,
};
use crate::sched::simulate::run_episode;
use crate::util::rng::Rng;

/// Threads the host can usefully run.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on `threads` OS threads (work-stealing via an
/// atomic cursor). Returns results in input order; with `threads <= 1`
/// this degrades to a plain sequential map, and for any thread count the
/// output is identical to the sequential one (tasks are independent).
pub fn run_parallel<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                done.lock().unwrap().push((i, r));
            });
        }
    });
    let mut out = done.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Counterfactual utilities of a whole policy pool on one job/trace,
/// normalized for the EG selector — the selector's inner loop, fanned
/// across cores. Episodes are independent and deterministic, so the
/// result equals the sequential evaluation exactly.
pub fn counterfactual_utilities(
    specs: &[PolicySpec],
    job: &Job,
    trace: &SpotTrace,
    models: &Models,
    env: &PolicyEnv,
    threads: usize,
) -> Vec<f64> {
    run_parallel(specs, threads, |_, spec| {
        let mut policy = spec.build(env);
        let r = run_episode(job, trace, models, policy.as_mut());
        job.normalize_utility(r.utility, models.on_demand_price)
    })
}

/// Algorithm 2 with the per-job counterfactual pool evaluation (112
/// episodes per job) fanned across `threads` cores. Produces exactly the
/// same [`SelectionOutcome`] as [`crate::sched::selector::run_selection`]
/// — only faster.
pub fn run_selection_parallel(
    specs: &[PolicySpec],
    jobs: &JobGenerator,
    models: &Models,
    trace_gen: &TraceGenerator,
    predictor_at: impl FnMut(usize) -> PredictorKind,
    cfg: &SelectionConfig,
    threads: usize,
) -> SelectionOutcome {
    run_selection_with(
        specs,
        jobs,
        models,
        trace_gen,
        predictor_at,
        cfg,
        |specs, job, trace, models, env| {
            counterfactual_utilities(specs, job, trace, models, env, threads)
        },
    )
}

/// A self-contained fleet experiment: how many jobs across how many
/// regions, under which market/job/noise calibration. The unit of work
/// for [`run_fleet_sweep`].
#[derive(Debug, Clone)]
pub struct FleetScenario {
    pub n_jobs: usize,
    pub n_regions: usize,
    pub seed: u64,
    pub market: GeneratorConfig,
    pub jobs: JobGenerator,
    pub models: Models,
    pub noise: NoiseSpec,
    pub migration: MigrationModel,
    pub migration_patience: usize,
    /// Arrival spacing: job k arrives at `(k % 4) * stagger` (0 = all at
    /// slot 0).
    pub stagger: usize,
}

impl FleetScenario {
    /// Paper-calibrated scenario.
    pub fn new(n_jobs: usize, n_regions: usize, seed: u64) -> Self {
        assert!(n_jobs >= 1 && n_regions >= 1);
        FleetScenario {
            n_jobs,
            n_regions,
            seed,
            market: GeneratorConfig::default(),
            jobs: JobGenerator::default(),
            models: Models::paper_default(),
            noise: NoiseSpec::fixed_mag_uniform(0.1),
            migration: MigrationModel::default(),
            migration_patience: 2,
            stagger: 0,
        }
    }

    pub fn with_stagger(mut self, stagger: usize) -> Self {
        self.stagger = stagger;
        self
    }

    /// Materialize the engine and job roster. Policies are drawn
    /// round-robin from [`fleet_roster`]; tiers and home regions cycle.
    ///
    /// The scenario seed fans out into three domain-separated streams —
    /// region traces, job sampling, and per-job predictor noise — so no
    /// two of them ever consume the same PRNG sequence (a shared stream
    /// would correlate a job's forecast errors with the very market it
    /// runs on and bias sweep statistics).
    pub fn build(&self) -> (FleetEngine, Vec<FleetJobSpec>) {
        const JOBS_STREAM: u64 = 0x9E37_79B9_7F4A_7C15;
        const NOISE_STREAM: u64 = 0xD1B5_4A32_D192_ED03;
        let gen = TraceGenerator::new(self.market.clone());
        let regions = RegionSet::generated(self.n_regions, &gen, self.seed)
            .with_migration(self.migration);
        let engine = FleetEngine::new(self.models, regions)
            .with_migration_patience(self.migration_patience);
        let roster = fleet_roster();
        let mut rng = Rng::new(self.seed ^ JOBS_STREAM);
        let specs = (0..self.n_jobs)
            .map(|k| {
                let job = self.jobs.sample(&mut rng);
                FleetJobSpec {
                    job,
                    policy: roster[k % roster.len()],
                    predictor: PredictorKind::Noisy(self.noise),
                    seed: self.seed
                        ^ NOISE_STREAM
                        ^ (k as u64 + 1).wrapping_mul(0x9E37_79B9),
                    tier: Tier::cycle(k),
                    home_region: k % self.n_regions,
                    arrival: (k % 4) * self.stagger,
                }
            })
            .collect();
        (engine, specs)
    }

    /// Build and run.
    pub fn run(&self) -> FleetResult {
        let (engine, specs) = self.build();
        engine.run(&specs)
    }
}

/// The policy mix synthetic fleets cycle through: the three baselines,
/// a mid-grid AHANP, and three representative AHAP corners.
pub fn fleet_roster() -> Vec<PolicySpec> {
    vec![
        PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
        PolicySpec::Msu,
        PolicySpec::Ahanp { sigma: 0.5 },
        PolicySpec::UniformProgress,
        PolicySpec::Ahap { omega: 5, v: 2, sigma: 0.9 },
        PolicySpec::OdOnly,
        PolicySpec::Ahap { omega: 1, v: 1, sigma: 0.5 },
    ]
}

/// Run many scenarios across `threads` cores (the fig11 bench's outer
/// loop and the CLI's `fleet --sweeps` path).
pub fn run_fleet_sweep(
    scenarios: &[FleetScenario],
    threads: usize,
) -> Vec<FleetResult> {
    run_parallel(scenarios, threads, |_, sc| sc.run())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_parallel_preserves_order_and_values() {
        let items: Vec<usize> = (0..97).collect();
        let seq = run_parallel(&items, 1, |i, &x| i * 1000 + x * x);
        let par = run_parallel(&items, 4, |i, &x| i * 1000 + x * x);
        assert_eq!(seq, par);
        assert_eq!(seq.len(), 97);
        assert_eq!(seq[3], 3 * 1000 + 9);
    }

    #[test]
    fn run_parallel_handles_empty_and_oversubscription() {
        let empty: Vec<u32> = vec![];
        assert!(run_parallel(&empty, 8, |_, &x| x).is_empty());
        let one = [5u32];
        assert_eq!(run_parallel(&one, 64, |_, &x| x * 2), vec![10]);
    }

    #[test]
    fn scenario_is_deterministic() {
        let sc = FleetScenario::new(6, 2, 11).with_stagger(3);
        assert_eq!(sc.run(), sc.run());
    }

    #[test]
    fn sweep_parallel_equals_sequential() {
        let scenarios: Vec<FleetScenario> =
            (0..4).map(|s| FleetScenario::new(4, 2, s)).collect();
        let seq = run_fleet_sweep(&scenarios, 1);
        let par = run_fleet_sweep(&scenarios, 4);
        assert_eq!(seq, par);
    }

    #[test]
    fn counterfactuals_match_sequential_episodes() {
        let specs = vec![
            PolicySpec::OdOnly,
            PolicySpec::Msu,
            PolicySpec::UniformProgress,
            PolicySpec::Ahanp { sigma: 0.5 },
        ];
        let job = Job::paper_reference();
        let models = Models::paper_default();
        let trace = TraceGenerator::calibrated().generate(3).slice_from(40);
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            9,
        );
        let par =
            counterfactual_utilities(&specs, &job, &trace, &models, &env, 4);
        let seq: Vec<f64> = specs
            .iter()
            .map(|s| {
                let mut p = s.build(&env);
                let r = run_episode(&job, &trace, &models, p.as_mut());
                job.normalize_utility(r.utility, models.on_demand_price)
            })
            .collect();
        assert_eq!(par, seq);
    }
}
