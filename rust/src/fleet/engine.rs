//! The fleet engine: many concurrent jobs, each driven by its own
//! [`Policy`], stepped slot-by-slot across multiple regional spot
//! markets with *shared* capacity. Jobs in a region compete through the
//! capacity arbiter instead of each seeing a private market; a job that
//! starves long enough may migrate to a better region (paying the
//! migration model).
//!
//! The load-bearing invariant, enforced by `tests/fleet_integration.rs`
//! across the whole 112-policy pool: a fleet of **one job in one
//! region** produces an [`EpisodeResult`] bit-for-bit identical to
//! [`run_episode`]. Every accounting expression below mirrors the
//! episode simulator's exactly (same operations, same order), and the
//! end-of-horizon settlement is the shared
//! [`crate::sched::simulate::settle_episode`].

use crate::fleet::capacity::{arbitrate, SpotRequest, Tier};
use crate::fleet::region::{MigrationMode, MigrationModel, RegionSet};
use crate::forecast::arima::{ArimaConfig, ArimaPredictor};
use crate::forecast::cache::{ForecastCachePool, RegionForecasts, SharedForecaster};
use crate::forecast::predictor::{Forecast, Predictor};
use crate::obs::{Counter, Event, MigrationPhase, Recorder};
use crate::sched::ahap::SolverKind;
use crate::sched::job::Job;
use crate::sched::policy::{
    Allocation, Models, Policy, RegionDecision, RegionSnapshot, RegionView,
    SlotContext,
};
use crate::sched::pool::{PolicyEnv, PolicySpec, PredictorKind};
use crate::sched::simulate::{settle_episode, EpisodeResult};

/// One job's membership in a fleet: the job itself, the policy that
/// drives it, and its fleet-level attributes.
#[derive(Debug, Clone)]
pub struct FleetJobSpec {
    pub job: Job,
    pub policy: PolicySpec,
    pub predictor: PredictorKind,
    /// Seed for the policy's (noisy) predictor.
    pub seed: u64,
    /// Priority tier for capacity arbitration.
    pub tier: Tier,
    /// Region the job starts in.
    pub home_region: usize,
    /// Global slot at which the job arrives (0 = fleet start).
    pub arrival: usize,
}

impl FleetJobSpec {
    /// A job with fleet defaults: normal tier, region 0, arrival 0.
    pub fn new(job: Job, policy: PolicySpec, predictor: PredictorKind) -> Self {
        FleetJobSpec {
            job,
            policy,
            predictor,
            seed: 0,
            tier: Tier::Normal,
            home_region: 0,
            arrival: 0,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_tier(mut self, tier: Tier) -> Self {
        self.tier = tier;
        self
    }

    pub fn in_region(mut self, r: usize) -> Self {
        self.home_region = r;
        self
    }

    pub fn arriving_at(mut self, slot: usize) -> Self {
        self.arrival = slot;
        self
    }
}

/// Per-job outcome: the episode-equivalent result plus fleet-level
/// facts (where it ran, how often it moved).
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    /// Policy label (for reports).
    pub label: String,
    pub tier: Tier,
    pub home_region: usize,
    pub final_region: usize,
    pub migrations: u32,
    pub episode: EpisodeResult,
}

/// Aggregate outcome of a fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetResult {
    pub jobs: Vec<JobOutcome>,
    /// Global slots simulated.
    pub slots: usize,
    pub total_utility: f64,
    pub total_value: f64,
    pub total_cost: f64,
    /// Fraction of jobs meeting their soft deadline.
    pub on_time_rate: f64,
    pub total_preemptions: u64,
    pub total_migrations: u32,
    /// Mean granted/available spot fraction per region (slots with zero
    /// availability excluded).
    pub region_utilization: Vec<f64>,
    /// Spot granted per region per global slot (for conservation checks).
    pub region_granted: Vec<Vec<u32>>,
    /// Spot available per region per global slot.
    pub region_avail: Vec<Vec<u32>>,
}

impl FleetResult {
    /// Mean utility per job.
    pub fn mean_utility(&self) -> f64 {
        if self.jobs.is_empty() {
            0.0
        } else {
            self.total_utility / self.jobs.len() as f64
        }
    }
}

/// One job's committed per-slot behavior from a recorded fleet run,
/// indexed by the job's *local* slot (0 = its arrival slot): the
/// pre-arbitration allocation it requested and the region it occupied.
/// Replaying a committed trace re-submits exactly these requests to the
/// arbiter — the job's *choices* are frozen, while its *outcomes*
/// (grants, preemptions, progress) still respond to whatever contention
/// the counterfactual fleet produces.
#[derive(Debug, Clone, PartialEq)]
pub struct CommittedTrace {
    /// Post-clamp allocation requested at each local slot.
    pub wants: Vec<Allocation>,
    /// Region occupied at each local slot (migrations appear as a
    /// change between consecutive entries).
    pub regions: Vec<usize>,
}

/// A recorded fleet run: the full result plus every job's committed
/// trace, replayable through [`FleetEngine::run_with_override`]. This is
/// what makes per-round counterfactuals cheap: the fleet is simulated
/// live **once**, then each candidate policy is swapped into one job's
/// slot while everyone else replays — no policy or predictor rebuilds
/// for the rest of the fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct CommittedRun {
    pub result: FleetResult,
    pub traces: Vec<CommittedTrace>,
}

/// What drives a job through the fleet loop: a live policy deciding
/// slot-by-slot, or a committed trace replaying recorded choices.
enum JobDriver<'a> {
    Live(Box<dyn Policy>),
    Replay(&'a CommittedTrace),
}

/// Internal per-job simulation state.
struct JobState<'a> {
    driver: JobDriver<'a>,
    region: usize,
    progress: f64,
    prev_total: u32,
    prev_avail: u32,
    held_spot: u32,
    decisions: Vec<Allocation>,
    reconfigs: u32,
    spot_slots: u32,
    on_demand_slots: u32,
    preemptions: u64,
    cost: f64,
    /// Consecutive slots the job wanted spot and got none.
    starved: usize,
    migrations: u32,
    /// Apply the migration μ to the next slot's progress.
    migration_mu_pending: bool,
    /// Policy-emitted migration intent for this slot (Policy mode,
    /// region-aware live policies only), validated and booked in
    /// phase 3.
    intent: Option<usize>,
    /// 1-based local completion slot, if the job finished in-horizon.
    completion_slot: Option<usize>,
    /// No longer simulated (completed or horizon exhausted).
    done: bool,
    /// This slot's clamped request + observation (phase 1 → phase 3).
    pending: Option<(Allocation, crate::market::market::MarketObs)>,
}

impl JobState<'_> {
    /// Book a migration into `to` — one body for the intent path, the
    /// starvation reflex, and replayed recorded moves (the engine-side
    /// twin of the replay `Cursor`'s booking; keeping a single copy is
    /// what the delta ≡ full bit-identity silently depends on). The
    /// starved reset is a no-op for replay drivers, which never read it.
    fn book_migration(&mut self, to: usize, mig: &MigrationModel) {
        self.region = to;
        self.cost += mig.cost;
        self.migrations += 1;
        self.held_spot = 0;
        self.migration_mu_pending = true;
        self.starved = 0;
    }
}

/// The multi-job, multi-region simulator.
#[derive(Debug, Clone)]
pub struct FleetEngine {
    pub models: Models,
    pub regions: RegionSet,
    /// Consecutive fully-starved slots before a job migrates to a
    /// better region; 0 disables the starvation reflex entirely.
    pub migration_patience: usize,
    /// How migration decisions are made: the starvation reflex only
    /// (the historical behavior, bit-for-bit), or policy-emitted intents
    /// as the primary path — region-aware policies plan `(region,
    /// allocation)` jointly from per-region forecasts; the reflex stays
    /// the fallback for policies that are not region-aware.
    pub migration_mode: MigrationMode,
    /// Shared per-(region, arrival) forecast caches for honest-ARIMA
    /// jobs: one fit per slot serves every such job — and, crucially,
    /// every counterfactual replay of a selection round, since engine
    /// clones share the pool. `None` = private per-policy fits (the
    /// reference path; results are bit-identical either way).
    forecasts: Option<ForecastCachePool>,
    /// Tracing handle — disabled (a no-op) by default. A traced run
    /// produces a bit-identical [`FleetResult`]; see [`crate::obs`].
    obs: Recorder,
    /// Worker threads for the event-driven stepper's per-slot region
    /// loop (capped at the region count; 1 = in-place sequential).
    pub(crate) threads: usize,
    /// Route [`run`](FleetEngine::run) / [`run_recorded`](FleetEngine::run_recorded)
    /// through the dense reference stepper instead of the event-driven
    /// one (see [`crate::fleet::events`]). The two are bit-identical.
    pub(crate) dense: bool,
    /// Eq. 10 window-solver backend handed to every AHAP policy the
    /// fleet builds (see [`SolverKind`]). The default (`Greedy`) is the
    /// historical behavior; `Warm` reproduces it bit-for-bit with
    /// incremental state (property-tested in
    /// `tests/warm_solver_properties.rs`).
    pub(crate) solver: SolverKind,
}

impl FleetEngine {
    pub fn new(models: Models, regions: RegionSet) -> Self {
        FleetEngine {
            models,
            regions,
            migration_patience: 2,
            migration_mode: MigrationMode::default(),
            forecasts: Some(ForecastCachePool::new()),
            obs: Recorder::disabled(),
            threads: 1,
            dense: false,
            solver: SolverKind::default(),
        }
    }

    /// Attach a tracing recorder (see [`crate::obs`]). [`run`] and
    /// [`run_recorded`] emit arbitration, preemption, migration-intent,
    /// and forecast-cache events into it; [`run_with_override`] never
    /// traces (a selection round replays many counterfactuals in
    /// parallel — tracing them would be both noisy and, merged into one
    /// stream, schedule-dependent).
    ///
    /// [`run`]: FleetEngine::run
    /// [`run_recorded`]: FleetEngine::run_recorded
    /// [`run_with_override`]: FleetEngine::run_with_override
    pub fn with_recorder(mut self, obs: Recorder) -> Self {
        self.obs = obs;
        self
    }

    pub fn with_migration_patience(mut self, patience: usize) -> Self {
        self.migration_patience = patience;
        self
    }

    pub fn with_migration_mode(mut self, mode: MigrationMode) -> Self {
        self.migration_mode = mode;
        self
    }

    /// Disable the shared forecast cache (per-policy ARIMA fits). Only
    /// useful as the baseline in equivalence tests and benches.
    pub fn without_shared_forecasts(mut self) -> Self {
        self.forecasts = None;
        self
    }

    /// Shard the event-driven stepper's per-slot region loop across up
    /// to `threads` OS threads (capped at the region count). Regions
    /// within a slot are independent — cross-region effects (migrations)
    /// are reconciled sequentially between slots — so the result is
    /// bit-identical for any thread count (property-tested in
    /// `tests/fleet_engine_equivalence.rs`). No effect on the dense
    /// stepper, which stays single-threaded.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Route full runs through the dense reference stepper — the
    /// historical water-fill-every-region-every-slot loop — instead of
    /// the event-driven one. The two are bit-identical; the dense loop
    /// survives as the executable specification the event-driven engine
    /// is property-tested (and benchmarked) against.
    pub fn with_dense_stepper(mut self) -> Self {
        self.dense = true;
        self
    }

    /// Select the Eq. 10 window-solver backend for every AHAP policy
    /// the fleet builds. `Warm` and the deterministic portfolio
    /// (`budget_us: None`) reproduce the default run bit-for-bit.
    pub fn with_solver(mut self, solver: SolverKind) -> Self {
        self.solver = solver;
        self
    }

    /// Run the fleet to quiescence: every job either completes or
    /// exhausts its deadline horizon (post-deadline termination is
    /// settled analytically, exactly as in `run_episode`).
    ///
    /// Routed through the event-driven stepper
    /// ([`crate::fleet::events`]) unless
    /// [`with_dense_stepper`](FleetEngine::with_dense_stepper) was
    /// requested — the results are bit-identical either way.
    pub fn run(&self, specs: &[FleetJobSpec]) -> FleetResult {
        let result = if self.dense {
            self.run_inner(specs, self.live_drivers(specs), false, &self.obs).0
        } else {
            crate::fleet::events::run_event_driven(
                self, specs, false, &self.obs,
            )
            .0
        };
        self.emit_forecast_stats();
        result
    }

    /// [`FleetEngine::run`], additionally recording every job's
    /// committed trace (per-slot requests and regions) so individual
    /// jobs can later be re-simulated under [`run_with_override`]
    /// without rebuilding the rest of the fleet.
    ///
    /// [`run_with_override`]: FleetEngine::run_with_override
    pub fn run_recorded(&self, specs: &[FleetJobSpec]) -> CommittedRun {
        let (result, traces) = if self.dense {
            self.run_inner(specs, self.live_drivers(specs), true, &self.obs)
        } else {
            crate::fleet::events::run_event_driven(
                self, specs, true, &self.obs,
            )
        };
        self.emit_forecast_stats();
        CommittedRun { result, traces }
    }

    /// Emit the shared forecast-cache statistics as one
    /// `forecast_cache` event (traced full runs only; a no-op when the
    /// recorder is disabled or the pool is off).
    fn emit_forecast_stats(&self) {
        if !self.obs.is_enabled() {
            return;
        }
        let Some(pool) = &self.forecasts else { return };
        let s = pool.stats();
        self.obs.emit(|| Event::ForecastCache {
            round: self.obs.round(),
            caches: s.caches,
            slots: s.slots,
            hits: s.hits,
            misses: s.misses,
            fits_price: s.fits_price,
            fits_avail: s.fits_avail,
        });
    }

    /// Re-run the fleet with job `live_job`'s policy swapped for
    /// `policy`, every other job replaying its committed trace from a
    /// prior [`run_recorded`]. The replayed jobs re-submit exactly their
    /// recorded requests and re-enter their recorded regions (paying the
    /// recorded migration costs); the arbiter re-decides every grant
    /// under the new contention, so the live job's outcome — and the
    /// replayed jobs' grants, preemptions, and progress — genuinely
    /// reflect the counterfactual.
    ///
    /// Swapping in the *same* policy the recorded run used reproduces
    /// the recorded [`FleetResult`] bit-for-bit (enforced in
    /// `tests/fleet_integration.rs`): identical requests from everyone
    /// arbitrate identically, slot by slot.
    ///
    /// [`run_recorded`]: FleetEngine::run_recorded
    pub fn run_with_override(
        &self,
        specs: &[FleetJobSpec],
        traces: &[CommittedTrace],
        live_job: usize,
        policy: PolicySpec,
    ) -> FleetResult {
        assert_eq!(
            specs.len(),
            traces.len(),
            "one committed trace per fleet job"
        );
        assert!(live_job < specs.len(), "live_job out of range");
        let mut swapped = specs[live_job].clone();
        swapped.policy = policy;
        let drivers: Vec<JobDriver> = specs
            .iter()
            .enumerate()
            .map(|(j, _)| {
                if j == live_job {
                    JobDriver::Live(self.build_policy(&swapped))
                } else {
                    JobDriver::Replay(&traces[j])
                }
            })
            .collect();
        let mut all = specs.to_vec();
        all[live_job] = swapped;
        // Overridden runs deliberately bypass the recorder: a selection
        // round replays many of them in parallel, and tracing them would
        // make the merged stream (and the disabled-path cost of every
        // counterfactual) depend on the round's schedule.
        //
        // They also always take the dense stepper: replay drivers book
        // their recorded migrations at slot *entry* (a mid-slot
        // cross-region mutation the event-driven engine's sharded phase
        // structure has no seam for), and a selection round's replays
        // are many small fleets where the dense loop is already the
        // right tool.
        self.run_inner(&all, drivers, false, &Recorder::disabled()).0
    }

    /// The policy environment for a job running in `region`: the
    /// region's trace from the job's arrival onward (the same view
    /// `run_episode` gets, so oracle/noisy predictors index local slots
    /// correctly). `rebuild` is false for the job's initial build, true
    /// for a mid-episode rebuild (a migration) — the rebuild *slot* is
    /// deliberately not a parameter: shared forecasters self-align on
    /// the slots the rebuilt policy observes.
    ///
    /// Honest-ARIMA forecasting differs by migration mode:
    ///
    /// - **Starvation** (historical, bit-compatible): only the initial
    ///   home-region build gets the shared forecast cache; migration
    ///   rebuilds replan *cold* with private predictors — a policy
    ///   rebuilt at slot t has only its own subsequent observations.
    /// - **Policy** (region-aware): every build — initial or rebuild,
    ///   any region — is served by the cross-region cache set
    ///   ([`RegionForecasts`] over the engine's pool), so a migrated job
    ///   replans *warm* against the destination's full observed history
    ///   from the same fits its candidate snapshots were served from.
    ///   When the pool is disabled (the private reference path), the
    ///   rebuild gets a fresh forecaster over the same slice — every
    ///   served value is a pure function of `(trace, cfg, slot)`, so
    ///   pooled and fresh are bit-identical.
    ///
    /// `pub(crate)` so [`crate::fleet::replay`] can mirror the live
    /// learner's policy (re)builds exactly.
    pub(crate) fn policy_env(
        &self,
        s: &FleetJobSpec,
        region: usize,
        rebuild: bool,
    ) -> PolicyEnv {
        let trace = self.regions.get(region).trace.slice_from(s.arrival);
        let mut forecasts = None;
        if let PredictorKind::Arima(cfg) = &s.predictor {
            if !rebuild {
                if region == s.home_region {
                    if let Some(pool) = &self.forecasts {
                        forecasts = Some(pool.for_slice(
                            region,
                            s.arrival,
                            *cfg,
                            || trace.clone(),
                        ));
                    }
                }
            } else if self.migration_mode == MigrationMode::Policy {
                // Warm replan: the rebuilt policy reads the
                // destination's full observed history through a
                // slot-advancing forecaster — the pooled one when the
                // pool is on, an identically-behaving fresh one
                // otherwise (its values are a pure function of
                // `(trace, cfg, slot)`, so pooled and fresh agree
                // bit-for-bit at every refit cadence).
                forecasts = Some(match &self.forecasts {
                    Some(pool) => RegionForecasts::new(pool, *cfg)
                        .forecaster(region, s.arrival, || trace.clone()),
                    None => SharedForecaster::new(trace.clone(), *cfg),
                });
            }
            // Starvation-mode rebuilds: private, cold (historical).
        }
        let mut env = PolicyEnv::new(s.predictor.clone(), trace, s.seed);
        env.forecasts = forecasts;
        env.solver = self.solver;
        env
    }

    /// Build (and reset) a policy for a job spec against `region` —
    /// the single construction path behind initial builds and both
    /// migration-rebuild sites (starvation reflex and policy intents).
    fn policy_for(
        &self,
        s: &FleetJobSpec,
        region: usize,
        rebuild: bool,
    ) -> Box<dyn Policy> {
        let env = self.policy_env(s, region, rebuild);
        let mut policy = s.policy.build(&env);
        policy.reset();
        policy
    }

    /// Build (and reset) the live policy for a job spec.
    pub(crate) fn build_policy(&self, s: &FleetJobSpec) -> Box<dyn Policy> {
        self.policy_for(s, s.home_region, false)
    }

    /// Rebuild a job's policy against `region` after a migration (cold
    /// in Starvation mode, warm in Policy mode — see
    /// [`policy_env`](FleetEngine::policy_env)). Shared with
    /// [`crate::fleet::replay`].
    pub(crate) fn rebuild_policy(
        &self,
        s: &FleetJobSpec,
        region: usize,
    ) -> Box<dyn Policy> {
        self.policy_for(s, region, true)
    }

    /// Validate a policy-emitted migration intent: only honored in
    /// Policy mode, toward a real *other* region, only when the
    /// migration cost is finite (an unpayable model disables migration),
    /// and never at the job's final decision slot — a move books at the
    /// end of the slot and takes effect at the next one, so there it
    /// could never run and its charge would be pure loss.
    pub(crate) fn validate_intent(
        &self,
        intent: Option<usize>,
        current: usize,
        s: &FleetJobSpec,
        local_t: usize,
    ) -> Option<usize> {
        intent.filter(|&r| {
            self.migration_mode == MigrationMode::Policy
                && r < self.regions.len()
                && r != current
                && self.regions.migration.cost.is_finite()
                && local_t + 1 < s.job.deadline
        })
    }

    /// Why an emitted intent failed [`validate_intent`]: the first
    /// failing condition, in validation order. Trace diagnostics only —
    /// never consulted on the simulation path.
    ///
    /// [`validate_intent`]: FleetEngine::validate_intent
    pub(crate) fn intent_reject_reason(
        &self,
        to: usize,
        current: usize,
        s: &FleetJobSpec,
        local_t: usize,
    ) -> &'static str {
        if self.migration_mode != MigrationMode::Policy {
            "not_policy_mode"
        } else if to >= self.regions.len() {
            "out_of_range"
        } else if to == current {
            "same_region"
        } else if !self.regions.migration.cost.is_finite() {
            "unpayable"
        } else {
            "last_decision_slot"
        }
    }

    /// The candidate-region forecast a region-aware policy sees:
    /// honest-ARIMA jobs read the shared cross-region cache (or a
    /// bit-identical private fit on the reference path); oracle and
    /// noisy jobs read the true trace — cross-region *scouting* is
    /// forecast-driven, while noise stays confined to the job's own
    /// market predictor.
    fn candidate_forecast(
        &self,
        s: &FleetJobSpec,
        region: usize,
        t: usize,
        local_t: usize,
        h: usize,
    ) -> Forecast {
        if h == 0 {
            return Forecast { price: Vec::new(), avail: Vec::new() };
        }
        match &s.predictor {
            PredictorKind::Arima(cfg) => {
                self.arima_region_forecast(region, s.arrival, *cfg, local_t, h)
            }
            PredictorKind::Oracle | PredictorKind::Noisy(_) => {
                let mut price = Vec::with_capacity(h);
                let mut avail = Vec::with_capacity(h);
                for i in 0..h {
                    price.push(self.regions.price(region, t + 1 + i));
                    avail.push(self.regions.avail(region, t + 1 + i) as f64);
                }
                Forecast { price, avail }
            }
        }
    }

    /// Honest forecast of `region`'s market issued at local slot
    /// `local_t` — from the shared cross-region cache when the pool is
    /// on, from a private predictor replaying the same observe/predict
    /// sequence otherwise. The private replay predicts every slot (as
    /// the cache's advance loop does), so the refit cadence — and with
    /// it the fitted model — matches the cache bit-for-bit at any
    /// `refit_every`.
    fn arima_region_forecast(
        &self,
        region: usize,
        arrival: usize,
        cfg: ArimaConfig,
        local_t: usize,
        h: usize,
    ) -> Forecast {
        let make_trace = || self.regions.get(region).trace.slice_from(arrival);
        match &self.forecasts {
            Some(pool) => RegionForecasts::new(pool, cfg)
                .forecast(region, arrival, local_t, h, make_trace),
            None => {
                let tr = make_trace();
                let mut p = ArimaPredictor::configured(cfg);
                let mut fc = Forecast { price: Vec::new(), avail: Vec::new() };
                for tt in 0..=local_t {
                    p.observe(tt, tr.price_at(tt), tr.avail_at(tt));
                    fc = p.predict(h);
                }
                fc
            }
        }
    }

    /// Snapshots of every region except `current` for a region-aware
    /// policy's slot view: each candidate's observed market this slot
    /// plus an ω-step forecast (ω = the policy's prediction window).
    /// A pure function of `(engine, spec, current, t)` — which is what
    /// lets [`crate::fleet::replay`] rebuild the exact view a live
    /// learner saw.
    pub(crate) fn region_snapshots(
        &self,
        s: &FleetJobSpec,
        current: usize,
        t: usize,
        local_t: usize,
    ) -> Vec<RegionSnapshot> {
        let h = s.policy.omega();
        (0..self.regions.len())
            .filter(|&r| r != current)
            .map(|r| RegionSnapshot {
                region: r,
                obs: self.regions.observe(
                    r,
                    t,
                    local_t,
                    self.models.on_demand_price,
                ),
                forecast: self.candidate_forecast(s, r, t, local_t, h),
            })
            .collect()
    }

    fn live_drivers(&self, specs: &[FleetJobSpec]) -> Vec<JobDriver<'static>> {
        specs
            .iter()
            .map(|s| JobDriver::Live(self.build_policy(s)))
            .collect()
    }

    /// The shared slot loop behind [`run`], [`run_recorded`], and
    /// [`run_with_override`]. Every accounting expression mirrors
    /// `run_episode`'s exactly (same operations, same order) — that is
    /// the 1-job/1-region equivalence invariant — and replay drivers
    /// differ from live ones *only* in where a slot's request and region
    /// come from.
    ///
    /// [`run`]: FleetEngine::run
    /// [`run_recorded`]: FleetEngine::run_recorded
    /// [`run_with_override`]: FleetEngine::run_with_override
    fn run_inner<'a>(
        &self,
        specs: &[FleetJobSpec],
        drivers: Vec<JobDriver<'a>>,
        record: bool,
        rec: &Recorder,
    ) -> (FleetResult, Vec<CommittedTrace>) {
        assert_eq!(specs.len(), drivers.len());
        for s in specs {
            assert!(
                s.home_region < self.regions.len(),
                "home_region {} out of range ({} regions)",
                s.home_region,
                self.regions.len()
            );
        }
        let horizon = specs
            .iter()
            .map(|s| s.arrival + s.job.deadline)
            .max()
            .unwrap_or(0);
        let n_regions = self.regions.len();

        let mut states: Vec<JobState> = specs
            .iter()
            .zip(drivers)
            .map(|(s, driver)| {
                JobState {
                    driver,
                    region: s.home_region,
                    progress: 0.0,
                    prev_total: 0,
                    prev_avail: 0,
                    held_spot: 0,
                    decisions: Vec::with_capacity(s.job.deadline),
                    reconfigs: 0,
                    spot_slots: 0,
                    on_demand_slots: 0,
                    preemptions: 0,
                    cost: 0.0,
                    starved: 0,
                    migrations: 0,
                    migration_mu_pending: false,
                    intent: None,
                    completion_slot: None,
                    done: false,
                    pending: None,
                }
            })
            .collect();

        let mut region_granted: Vec<Vec<u32>> = vec![Vec::with_capacity(horizon); n_regions];
        let mut region_avail: Vec<Vec<u32>> = vec![Vec::with_capacity(horizon); n_regions];
        let mut committed: Vec<CommittedTrace> = specs
            .iter()
            .map(|s| CommittedTrace {
                wants: Vec::with_capacity(if record { s.job.deadline } else { 0 }),
                regions: Vec::with_capacity(if record { s.job.deadline } else { 0 }),
            })
            .collect();

        for t in 0..horizon {
            // Phase 1 — every active job observes its region and decides
            // (or replays its committed decision).
            for (j, s) in specs.iter().enumerate() {
                let st = &mut states[j];
                st.pending = None;
                st.intent = None;
                if st.done || t < s.arrival {
                    continue;
                }
                let local_t = t - s.arrival;
                if local_t >= s.job.deadline {
                    st.done = true;
                    continue;
                }
                if let JobDriver::Replay(tr) = &st.driver {
                    if local_t < tr.regions.len() {
                        let region_now = tr.regions[local_t];
                        if region_now != st.region {
                            // The recorded migration, replayed: same
                            // cost, same cold-restart μ, same freed
                            // capacity. The live path books these at the
                            // decision slot and the replay at the
                            // arrival slot — invisible in the totals,
                            // identical at arbitration time.
                            st.book_migration(region_now, &self.regions.migration);
                        }
                    }
                }
                let obs = self.regions.observe(
                    st.region,
                    t,
                    local_t,
                    self.models.on_demand_price,
                );
                let region_now = st.region;
                let (want, intent) = match &mut st.driver {
                    JobDriver::Live(policy) => {
                        let ctx = SlotContext {
                            t: local_t,
                            obs,
                            progress: st.progress,
                            prev_total: st.prev_total,
                            prev_avail: st.prev_avail,
                            job: &s.job,
                            models: &self.models,
                        };
                        // Region-aware policies in Policy mode see the
                        // whole region set and may emit a migration
                        // intent; everyone else decides on the single
                        // market exactly as before. An unpayable
                        // migration model skips the view outright —
                        // decide_region with no viable move is exactly
                        // decide (also mirrored in fleet::replay).
                        let decision = if self.migration_mode
                            == MigrationMode::Policy
                            && n_regions > 1
                            && self.regions.migration.cost.is_finite()
                            && policy.region_aware()
                        {
                            let snaps = self.region_snapshots(
                                s, region_now, t, local_t,
                            );
                            let view = RegionView {
                                current: region_now,
                                candidates: &snaps,
                                migration: self.regions.migration.terms(),
                            };
                            policy.decide_region(&ctx, &view)
                        } else {
                            RegionDecision {
                                alloc: policy.decide(&ctx),
                                migrate_to: None,
                            }
                        };
                        let validated = self.validate_intent(
                            decision.migrate_to,
                            region_now,
                            s,
                            local_t,
                        );
                        if let Some(to) = decision.migrate_to {
                            rec.add(Counter::IntentsEmitted, 1);
                            rec.emit(|| Event::Migration {
                                round: rec.round(),
                                slot: t,
                                job: j,
                                from: region_now,
                                to,
                                phase: MigrationPhase::Emitted,
                                reason: None,
                            });
                            if validated.is_some() {
                                rec.emit(|| Event::Migration {
                                    round: rec.round(),
                                    slot: t,
                                    job: j,
                                    from: region_now,
                                    to,
                                    phase: MigrationPhase::Validated,
                                    reason: None,
                                });
                            } else {
                                rec.add(Counter::IntentsRejected, 1);
                                rec.emit(|| Event::Migration {
                                    round: rec.round(),
                                    slot: t,
                                    job: j,
                                    from: region_now,
                                    to,
                                    phase: MigrationPhase::Rejected,
                                    reason: Some(self.intent_reject_reason(
                                        to, region_now, s, local_t,
                                    )),
                                });
                            }
                        }
                        (decision.alloc.clamp_to_job(&s.job, obs.avail), validated)
                    }
                    // Recorded wants are post-clamp against the same
                    // job and the same observation (regions replay, so
                    // the trace lookup is identical) — re-clamping
                    // would be a no-op. Past the committed plan's end
                    // (the job completed there in the recorded run but
                    // is behind under this contention) its frozen
                    // choice is to buy nothing: it idles out the
                    // horizon and settles like any live job that did.
                    JobDriver::Replay(tr) => {
                        let w = if local_t < tr.wants.len() {
                            tr.wants[local_t]
                        } else {
                            Allocation::idle()
                        };
                        (w, None)
                    }
                };
                st.pending = Some((want, obs));
                st.intent = intent;
            }

            // Phase 2 — per-region shared-capacity arbitration.
            let mut spot_grant: Vec<u32> = vec![0; specs.len()];
            let mut preempted: Vec<u32> = vec![0; specs.len()];
            for r in 0..n_regions {
                let avail = self.regions.avail(r, t);
                let members: Vec<usize> = (0..specs.len())
                    .filter(|&j| states[j].pending.is_some() && states[j].region == r)
                    .collect();
                let requests: Vec<SpotRequest> = members
                    .iter()
                    .map(|&j| SpotRequest {
                        job: j,
                        tier: specs[j].tier,
                        want: states[j].pending.as_ref().unwrap().0.spot,
                        held: states[j].held_spot,
                    })
                    .collect();
                let grants = arbitrate(avail, &requests);
                let mut granted_sum = 0u32;
                for g in &grants {
                    spot_grant[g.job] = g.granted;
                    preempted[g.job] = g.preempted;
                    granted_sum += g.granted;
                }
                // Trace the arbitration outcome (one branch when off).
                if rec.is_enabled() && !members.is_empty() {
                    rec.add(Counter::Arbitrations, 1);
                    let requested: u32 = requests.iter().map(|q| q.want).sum();
                    let preempted_jobs =
                        grants.iter().filter(|g| g.preempted > 0).count();
                    rec.emit(|| Event::Arbitration {
                        round: rec.round(),
                        slot: t,
                        region: r,
                        avail,
                        requested,
                        granted: granted_sum,
                        contenders: members.len(),
                        preempted_jobs,
                    });
                    for g in &grants {
                        if g.preempted > 0 {
                            rec.add(Counter::Preemptions, 1);
                            rec.emit(|| Event::Preemption {
                                round: rec.round(),
                                slot: t,
                                region: r,
                                job: g.job,
                                lost: g.preempted,
                            });
                        }
                    }
                }
                region_granted[r].push(granted_sum);
                region_avail[r].push(avail);
            }

            // Phase 3 — per-job accounting (mirrors `run_episode`).
            for (j, s) in specs.iter().enumerate() {
                let st = &mut states[j];
                let Some((want, obs)) = st.pending.take() else {
                    continue;
                };
                let local_t = t - s.arrival;
                if record {
                    committed[j].wants.push(want);
                    committed[j].regions.push(st.region);
                }
                let spot = spot_grant[j];
                st.preemptions += preempted[j] as u64;
                st.held_spot = spot;
                let total = spot + want.on_demand;
                let mut mu = self.models.reconfig.mu(st.prev_total, total);
                if st.migration_mu_pending {
                    mu *= self.regions.migration.mu;
                    st.migration_mu_pending = false;
                }
                st.progress += mu * self.models.throughput.h(total);
                if total != st.prev_total {
                    st.reconfigs += 1;
                }
                st.spot_slots += spot;
                st.on_demand_slots += want.on_demand;
                let slot_cost = want.on_demand as f64 * obs.on_demand_price
                    + spot as f64 * obs.spot_price;
                st.cost += slot_cost;
                st.decisions.push(Allocation::new(want.on_demand, spot));
                st.prev_total = total;
                st.prev_avail = obs.avail;

                if st.progress >= s.job.workload - 1e-9 {
                    st.completion_slot = Some(local_t + 1);
                    st.done = true;
                    st.held_spot = 0; // capacity freed for the next slot
                    continue;
                }

                // Migration — live jobs only: a replayed job's
                // migrations come from its recorded region sequence,
                // applied at slot entry above.
                if matches!(st.driver, JobDriver::Replay(_)) {
                    continue;
                }
                // Two ways to starve: the job asked for spot and the
                // arbiter granted none (contention), or the policy
                // idled because the region cannot even support N^min
                // (spot-first policies like MSU idle rather than run
                // below the floor). The counter is maintained in every
                // mode (so state snapshots agree across modes), but the
                // reflex below only *acts* for non-region-aware
                // policies.
                if (want.spot > 0 && spot == 0)
                    || (total == 0 && obs.avail < s.job.n_min)
                {
                    st.starved += 1;
                } else {
                    st.starved = 0;
                }
                // A region-aware policy in Policy mode owns its moves:
                // its validated intent is booked here, and the
                // starvation reflex never overrides its plan.
                let suppress_reflex = self.migration_mode
                    == MigrationMode::Policy
                    && matches!(&st.driver, JobDriver::Live(p) if p.region_aware());
                if let Some(best) = st.intent.take() {
                    // Replan against the destination market, aligned to
                    // the local slot clock. In Policy mode the rebuilt
                    // policy plans *warm*: its predictor is served the
                    // destination's full observed history by the
                    // cross-region forecast cache.
                    let from = st.region;
                    st.book_migration(best, &self.regions.migration);
                    rec.add(Counter::MigrationsBooked, 1);
                    rec.emit(|| Event::Migration {
                        round: rec.round(),
                        slot: t,
                        job: j,
                        from,
                        to: best,
                        phase: MigrationPhase::Booked,
                        reason: Some("intent"),
                    });
                    st.driver =
                        JobDriver::Live(self.rebuild_policy(s, best));
                } else if !suppress_reflex
                    && self.migration_patience > 0
                    && n_regions > 1
                    && st.starved >= self.migration_patience
                {
                    // The starvation reflex: after `patience` starved
                    // slots, flee to the observably best region if it is
                    // strictly better. (In Starvation mode the rebuilt
                    // policy replans cold — a migration is a disruption
                    // — preserving the historical trajectories exactly.)
                    let best = self.regions.best_region(t);
                    if best != st.region
                        && self.regions.avail(best, t) > obs.avail
                    {
                        let from = st.region;
                        st.book_migration(best, &self.regions.migration);
                        rec.add(Counter::MigrationsBooked, 1);
                        rec.emit(|| Event::Migration {
                            round: rec.round(),
                            slot: t,
                            job: j,
                            from,
                            to: best,
                            phase: MigrationPhase::Booked,
                            reason: Some("reflex"),
                        });
                        st.driver =
                            JobDriver::Live(self.rebuild_policy(s, best));
                    }
                }
            }
        }

        let finals: Vec<JobFinal> = states
            .into_iter()
            .map(|st| JobFinal {
                region: st.region,
                progress: st.progress,
                cost: st.cost,
                decisions: st.decisions,
                spot_slots: st.spot_slots,
                on_demand_slots: st.on_demand_slots,
                preemptions: st.preemptions,
                reconfigs: st.reconfigs,
                migrations: st.migrations,
                completion_slot: st.completion_slot,
            })
            .collect();
        (
            self.assemble_result(
                specs,
                finals,
                horizon,
                region_granted,
                region_avail,
            ),
            committed,
        )
    }

    /// Settle every job and aggregate the fleet totals — one body shared
    /// by the dense and event-driven steppers, so the two can only
    /// diverge in *simulation*, never in settlement arithmetic. Every
    /// expression mirrors `run_episode`'s settlement exactly.
    pub(crate) fn assemble_result(
        &self,
        specs: &[FleetJobSpec],
        finals: Vec<JobFinal>,
        horizon: usize,
        region_granted: Vec<Vec<u32>>,
        region_avail: Vec<Vec<u32>>,
    ) -> FleetResult {
        assert_eq!(specs.len(), finals.len());
        let n_regions = self.regions.len();
        let jobs: Vec<JobOutcome> = specs
            .iter()
            .zip(finals)
            .map(|(s, fin)| {
                let slots_run = fin.decisions.len();
                let progress_at_deadline = fin.progress.min(s.job.workload);
                let (value, total_cost, completion) = settle_episode(
                    &s.job,
                    &self.models,
                    fin.progress,
                    slots_run,
                    fin.cost,
                    fin.completion_slot,
                );
                JobOutcome {
                    label: s.policy.label(),
                    tier: s.tier,
                    home_region: s.home_region,
                    final_region: fin.region,
                    migrations: fin.migrations,
                    episode: EpisodeResult {
                        utility: value - total_cost,
                        value,
                        cost: total_cost,
                        completion_slot: completion,
                        on_time: completion <= s.job.deadline,
                        progress_at_deadline,
                        decisions: fin.decisions,
                        spot_slots: fin.spot_slots,
                        on_demand_slots: fin.on_demand_slots,
                        preemptions: fin.preemptions,
                        reconfigs: fin.reconfigs,
                    },
                }
            })
            .collect();

        let n = jobs.len().max(1) as f64;
        let total_utility = jobs.iter().map(|j| j.episode.utility).sum();
        let total_value = jobs.iter().map(|j| j.episode.value).sum();
        let total_cost = jobs.iter().map(|j| j.episode.cost).sum();
        let on_time_rate =
            jobs.iter().filter(|j| j.episode.on_time).count() as f64 / n;
        let total_preemptions =
            jobs.iter().map(|j| j.episode.preemptions).sum();
        let total_migrations = jobs.iter().map(|j| j.migrations).sum();
        let region_utilization = (0..n_regions)
            .map(|r| {
                let mut used = 0u64;
                let mut cap = 0u64;
                for (g, a) in region_granted[r].iter().zip(&region_avail[r]) {
                    if *a > 0 {
                        used += *g as u64;
                        cap += *a as u64;
                    }
                }
                if cap == 0 {
                    0.0
                } else {
                    used as f64 / cap as f64
                }
            })
            .collect();

        FleetResult {
            jobs,
            slots: horizon,
            total_utility,
            total_value,
            total_cost,
            on_time_rate,
            total_preemptions,
            total_migrations,
            region_utilization,
            region_granted,
            region_avail,
        }
    }
}

/// One job's fully-simulated terminal state — the hand-off between a
/// stepper (dense [`FleetEngine::run_inner`]-style or event-driven
/// [`crate::fleet::events`]) and the shared settlement in
/// [`FleetEngine::assemble_result`].
#[derive(Debug, Clone)]
pub(crate) struct JobFinal {
    pub region: usize,
    pub progress: f64,
    pub cost: f64,
    pub decisions: Vec<Allocation>,
    pub spot_slots: u32,
    pub on_demand_slots: u32,
    pub preemptions: u64,
    pub reconfigs: u32,
    pub migrations: u32,
    /// 1-based local completion slot, if the job finished in-horizon.
    pub completion_slot: Option<usize>,
}

impl JobFinal {
    /// The state of a job that never ran a slot (settles exactly like a
    /// dense-stepper job whose `JobState` was never touched).
    pub(crate) fn fresh(region: usize) -> JobFinal {
        JobFinal {
            region,
            progress: 0.0,
            cost: 0.0,
            decisions: Vec::new(),
            spot_slots: 0,
            on_demand_slots: 0,
            preemptions: 0,
            reconfigs: 0,
            migrations: 0,
            completion_slot: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::region::{MigrationMode, MigrationModel, Region};
    use crate::market::trace::SpotTrace;
    use crate::sched::simulate::run_episode;

    fn job() -> Job {
        Job { workload: 80.0, deadline: 10, n_min: 1, n_max: 12, value: 120.0, gamma: 1.5 }
    }

    fn flat_trace(price: f64, avail: u32, slots: usize) -> SpotTrace {
        SpotTrace::new(vec![price; slots], vec![avail; slots])
    }

    fn engine_single(trace: SpotTrace) -> FleetEngine {
        FleetEngine::new(Models::paper_default(), RegionSet::single(trace))
    }

    #[test]
    fn one_job_one_region_equals_run_episode() {
        let j = job();
        let models = Models::paper_default();
        let trace = flat_trace(0.4, 8, 12);
        let spec = FleetJobSpec::new(
            j,
            PolicySpec::Msu,
            PredictorKind::Oracle,
        );
        let fleet = engine_single(trace.clone()).run(&[spec]);
        let env = PolicyEnv::new(PredictorKind::Oracle, trace.clone(), 0);
        let mut p = PolicySpec::Msu.build(&env);
        let solo = run_episode(&j, &trace, &models, p.as_mut());
        assert_eq!(fleet.jobs[0].episode, solo);
    }

    #[test]
    fn contention_caps_total_spot() {
        // Two MSU jobs share a region with 6 spot; each would take 12
        // alone. The arbiter must keep the sum within 6 every slot.
        let j = job();
        let trace = flat_trace(0.3, 6, 24);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle),
        ];
        let r = engine_single(trace).run(&specs);
        for (g, a) in r.region_granted[0].iter().zip(&r.region_avail[0]) {
            assert!(g <= a, "granted {g} exceeds availability {a}");
        }
        // Contention must bite: neither job can have taken 12 every slot.
        assert!(r.jobs.iter().all(|jo| jo.episode.spot_slots < 12 * 10));
    }

    #[test]
    fn high_tier_outperforms_low_tier_under_scarcity() {
        let j = job();
        let trace = flat_trace(0.3, 6, 24);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::Low),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::High),
        ];
        let r = engine_single(trace).run(&specs);
        assert!(
            r.jobs[1].episode.spot_slots > r.jobs[0].episode.spot_slots,
            "high tier {} vs low tier {}",
            r.jobs[1].episode.spot_slots,
            r.jobs[0].episode.spot_slots
        );
    }

    #[test]
    fn starved_job_migrates_and_pays_for_it() {
        let j = job();
        let dead = flat_trace(0.5, 0, 16);
        let rich = flat_trace(0.4, 12, 16);
        let regions = RegionSet::new(vec![
            Region { name: "dead".into(), trace: dead },
            Region { name: "rich".into(), trace: rich },
        ])
        .with_migration(MigrationModel::new(3.0, 0.5));
        let engine = FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(2);
        let spec = FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle);
        let r = engine.run(&[spec]);
        assert!(r.jobs[0].migrations >= 1);
        assert_eq!(r.jobs[0].final_region, 1);
        assert_eq!(r.total_migrations, r.jobs[0].migrations);
    }

    #[test]
    fn migration_disabled_keeps_job_home() {
        let j = job();
        let dead = flat_trace(0.5, 0, 16);
        let rich = flat_trace(0.4, 12, 16);
        let regions = RegionSet::new(vec![
            Region { name: "dead".into(), trace: dead },
            Region { name: "rich".into(), trace: rich },
        ]);
        let engine = FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(0);
        let spec = FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle);
        let r = engine.run(&[spec]);
        assert_eq!(r.jobs[0].migrations, 0);
        assert_eq!(r.jobs[0].final_region, 0);
    }

    /// The capacity-shift scenario: the home region's spot collapses at
    /// `shift` while another region's fills in (a provider rebalancing
    /// capacity — the correlated regional shift).
    fn shifting_regions(shift: usize, slots: usize) -> RegionSet {
        crate::fleet::region::capacity_shift_fixture(shift, slots)
    }

    #[test]
    fn policy_mode_with_unpayable_migration_matches_todays_trajectories() {
        // The acceptance degeneracy: patience 0 + Policy mode + infinite
        // migration cost must reproduce the historical (Starvation-mode)
        // run bit-for-bit — region-aware AHAP never emits an intent it
        // cannot pay for, and nothing else differs.
        let j = job();
        let regions = || shifting_regions(6, 16);
        let specs = vec![
            FleetJobSpec::new(
                j,
                PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
                PredictorKind::Oracle,
            ),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .in_region(1),
        ];
        let today = FleetEngine::new(Models::paper_default(), regions())
            .with_migration_patience(0)
            .run(&specs);
        let policy_driven = FleetEngine::new(
            Models::paper_default(),
            regions().with_migration(MigrationModel::unpayable()),
        )
        .with_migration_patience(0)
        .with_migration_mode(MigrationMode::Policy)
        .run(&specs);
        assert_eq!(policy_driven, today);
        assert_eq!(policy_driven.total_migrations, 0);
    }

    #[test]
    fn policy_mode_migrates_predictively_and_beats_the_reflex() {
        // Region 0 drains at slot 6, region 1 fills — the reactive
        // reflex can only move *after* starving there, while region-aware
        // AHAP prices region 1's forecast window and moves on its own.
        let j = Job {
            workload: 120.0,
            deadline: 16,
            n_min: 1,
            n_max: 12,
            value: 200.0,
            gamma: 1.5,
        };
        let spec = FleetJobSpec::new(
            j,
            PolicySpec::Ahap { omega: 5, v: 1, sigma: 0.7 },
            PredictorKind::Oracle,
        );
        let reactive = FleetEngine::new(Models::paper_default(), shifting_regions(6, 16))
            .with_migration_patience(2)
            .run(&[spec.clone()]);
        let predictive = FleetEngine::new(Models::paper_default(), shifting_regions(6, 16))
            .with_migration_patience(2)
            .with_migration_mode(MigrationMode::Policy)
            .run(&[spec]);
        assert!(
            predictive.jobs[0].migrations >= 1,
            "region-aware AHAP never moved: {:?}",
            predictive.jobs[0]
        );
        assert_eq!(predictive.jobs[0].final_region, 1);
        assert!(
            predictive.jobs[0].episode.utility > reactive.jobs[0].episode.utility,
            "predictive {} should beat reactive {}",
            predictive.jobs[0].episode.utility,
            reactive.jobs[0].episode.utility
        );
    }

    #[test]
    fn policy_mode_single_region_is_the_trivial_special_case() {
        // One region → empty candidate list → decide_region degenerates
        // to decide: the 1-job fleet still equals run_episode exactly.
        let j = job();
        let models = Models::paper_default();
        let trace = flat_trace(0.4, 8, 12);
        let spec = FleetJobSpec::new(
            j,
            PolicySpec::Ahap { omega: 3, v: 2, sigma: 0.5 },
            PredictorKind::Oracle,
        );
        let fleet = engine_single(trace.clone())
            .with_migration_mode(MigrationMode::Policy)
            .run(&[spec]);
        let env = PolicyEnv::new(PredictorKind::Oracle, trace.clone(), 0);
        let mut p =
            PolicySpec::Ahap { omega: 3, v: 2, sigma: 0.5 }.build(&env);
        let solo = run_episode(&j, &trace, &models, p.as_mut());
        assert_eq!(fleet.jobs[0].episode, solo);
    }

    #[test]
    fn starvation_reflex_still_drives_non_region_aware_policies_in_policy_mode() {
        // MSU is not region-aware: in Policy mode it keeps the reflex —
        // starving in the drained region, it still flees.
        let j = job();
        let spec = FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle);
        let r = FleetEngine::new(Models::paper_default(), shifting_regions(0, 16))
            .with_migration_patience(2)
            .with_migration_mode(MigrationMode::Policy)
            .run(&[spec]);
        assert!(r.jobs[0].migrations >= 1);
        assert_eq!(r.jobs[0].final_region, 1);
    }

    #[test]
    fn staggered_arrival_shifts_the_market_window() {
        let j = job();
        // Spot only exists from slot 5 on; a job arriving at 5 sees it
        // from its first local slot.
        let mut avail = vec![0u32; 20];
        for a in avail.iter_mut().skip(5) {
            *a = 12;
        }
        let trace = SpotTrace::new(vec![0.3; 20], avail);
        let spec = FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
            .arriving_at(5);
        let r = engine_single(trace).run(&[spec]);
        assert!(r.jobs[0].episode.spot_slots > 0);
        assert_eq!(r.slots, 15);
    }

    #[test]
    fn recorded_traces_align_with_ran_slots() {
        let j = job();
        let trace = flat_trace(0.3, 6, 24);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle),
            FleetJobSpec::new(j, PolicySpec::UniformProgress, PredictorKind::Oracle)
                .arriving_at(3),
        ];
        let rec = engine_single(trace).run_recorded(&specs);
        assert_eq!(rec.traces.len(), 2);
        for (jo, tr) in rec.result.jobs.iter().zip(&rec.traces) {
            // one recorded want + region per slot the job actually ran
            assert_eq!(tr.wants.len(), jo.episode.decisions.len());
            assert_eq!(tr.regions.len(), tr.wants.len());
            assert!(tr.regions.iter().all(|&r| r == 0));
        }
        // run_recorded's result is exactly run's
        assert_eq!(rec.result, engine_single(flat_trace(0.3, 6, 24)).run(&specs));
    }

    #[test]
    fn override_with_committed_policy_is_identity() {
        // Swapping a job's own policy back in (others replaying) must
        // reproduce the recorded contended run bit-for-bit.
        let j = job();
        let trace = flat_trace(0.3, 6, 24);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::High),
            FleetJobSpec::new(j, PolicySpec::UniformProgress, PredictorKind::Oracle)
                .with_tier(Tier::Low),
        ];
        let engine = engine_single(trace);
        let rec = engine.run_recorded(&specs);
        for live in 0..specs.len() {
            let replayed = engine.run_with_override(
                &specs,
                &rec.traces,
                live,
                specs[live].policy,
            );
            assert_eq!(replayed, rec.result, "identity broke for job {live}");
        }
    }

    #[test]
    fn override_identity_holds_across_a_recorded_migration() {
        // The committed run migrates (dead home region); replaying the
        // other job's recorded regions must reproduce the result.
        let j = job();
        let dead = flat_trace(0.5, 0, 16);
        let rich = flat_trace(0.4, 12, 16);
        let regions = RegionSet::new(vec![
            Region { name: "dead".into(), trace: dead },
            Region { name: "rich".into(), trace: rich },
        ])
        .with_migration(MigrationModel::new(3.0, 0.5));
        let engine = FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(2);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .in_region(1),
        ];
        let rec = engine.run_recorded(&specs);
        assert!(rec.result.jobs[0].migrations >= 1, "scenario lost its migration");
        // job 1 is live again, job 0 (the migrant) replays its move
        let replayed =
            engine.run_with_override(&specs, &rec.traces, 1, PolicySpec::Msu);
        assert_eq!(replayed, rec.result);
        let migrant = &rec.traces[0];
        assert!(migrant.regions.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn override_swaps_one_policy_and_relieves_contention() {
        // Two MSU jobs fight over 6 spot. Swapping job 0 to OD-Only in
        // the counterfactual frees the whole region for the replaying
        // job 1, whose frozen requests now get fully granted.
        let j = job();
        let trace = flat_trace(0.3, 6, 24);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::High),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::Low),
        ];
        let engine = engine_single(trace);
        let rec = engine.run_recorded(&specs);
        let counter =
            engine.run_with_override(&specs, &rec.traces, 0, PolicySpec::OdOnly);
        assert_eq!(counter.jobs[0].label, PolicySpec::OdOnly.label());
        assert_eq!(counter.jobs[0].episode.spot_slots, 0);
        assert!(
            counter.jobs[1].episode.spot_slots
                > rec.result.jobs[1].episode.spot_slots,
            "replayed job should pick up the freed spot: {} vs {}",
            counter.jobs[1].episode.spot_slots,
            rec.result.jobs[1].episode.spot_slots
        );
    }

    #[test]
    fn traced_run_is_bit_identical_and_narrates_the_contention() {
        let j = job();
        let trace = flat_trace(0.3, 6, 24);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::High),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::Low),
        ];
        let plain = engine_single(trace.clone()).run(&specs);
        let rec = crate::obs::Recorder::enabled();
        let traced =
            engine_single(trace).with_recorder(rec.clone()).run(&specs);
        assert_eq!(traced, plain, "tracing must not perturb the run");
        let log = rec.finish().unwrap();
        let has = |kind: &str| {
            log.lines
                .iter()
                .any(|l| l.starts_with(&format!("{{\"kind\":\"{kind}\"")))
        };
        assert!(has("arbitration"));
        assert!(has("forecast_cache"));
        assert!(has("summary"));
        let counters: std::collections::HashMap<_, _> =
            log.counters.iter().copied().collect();
        assert!(counters["arbitrations"] > 0);
    }

    #[test]
    fn traced_migration_books_with_a_reason() {
        let j = job();
        let dead = flat_trace(0.5, 0, 16);
        let rich = flat_trace(0.4, 12, 16);
        let regions = RegionSet::new(vec![
            Region { name: "dead".into(), trace: dead },
            Region { name: "rich".into(), trace: rich },
        ])
        .with_migration(MigrationModel::new(3.0, 0.5));
        let rec = crate::obs::Recorder::enabled();
        let engine = FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(2)
            .with_recorder(rec.clone());
        let spec = FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle);
        let r = engine.run(&[spec]);
        assert!(r.jobs[0].migrations >= 1);
        let log = rec.finish().unwrap();
        assert!(log.lines.iter().any(|l| {
            l.contains("\"kind\":\"migration\"")
                && l.contains("\"phase\":\"booked\"")
                && l.contains("\"reason\":\"reflex\"")
        }));
        let counters: std::collections::HashMap<_, _> =
            log.counters.iter().copied().collect();
        assert_eq!(counters["migrations_booked"] as u32, r.total_migrations);
    }

    #[test]
    fn aggregates_are_consistent() {
        let j = job();
        let trace = flat_trace(0.4, 10, 24);
        let specs: Vec<FleetJobSpec> = (0..4)
            .map(|k| {
                FleetJobSpec::new(j, PolicySpec::UniformProgress, PredictorKind::Oracle)
                    .with_tier(Tier::cycle(k))
            })
            .collect();
        let r = engine_single(trace).run(&specs);
        let u: f64 = r.jobs.iter().map(|jo| jo.episode.utility).sum();
        assert!((r.total_utility - u).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&r.on_time_rate));
        for util in &r.region_utilization {
            assert!((0.0..=1.0).contains(util));
        }
    }
}
