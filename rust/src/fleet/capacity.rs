//! Shared-capacity arbitration: all jobs in a region submit their
//! per-slot spot requests and the arbiter grants under the regional
//! availability cap — fair-share water-filling within a priority tier,
//! higher tiers served first, with cascading preemption when
//! availability drops below what the fleet collectively holds.
//!
//! The contract the fleet engine relies on:
//!
//! - **capacity conservation** — `Σ granted ≤ avail` every slot;
//! - **single-tenant degeneracy** — with one requester, `granted =
//!   min(want, avail)` and `preempted = held − min(held, avail)`,
//!   exactly the per-job [`crate::market::market::SpotMarket`] semantics
//!   (this is what makes a 1-job fleet reproduce `run_episode`);
//! - **determinism** — grants depend only on `(avail, requests)`, with
//!   ties broken by job id.

/// Scheduling priority tier; higher tiers are granted (and keep their
/// instances) first. Within a tier capacity is fair-shared.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    Low,
    Normal,
    High,
}

impl Tier {
    /// Round-robin tier assignment for synthetic fleets.
    pub fn cycle(i: usize) -> Tier {
        match i % 3 {
            0 => Tier::High,
            1 => Tier::Normal,
            _ => Tier::Low,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Tier::Low => "low",
            Tier::Normal => "normal",
            Tier::High => "high",
        }
    }
}

/// One job's spot demand for the current slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpotRequest {
    /// Fleet-wide job index (tie-break key; must be unique per call).
    pub job: usize,
    pub tier: Tier,
    /// Spot instances the job's policy wants this slot.
    pub want: u32,
    /// Spot instances the job held at the end of the previous slot
    /// (for forced-preemption accounting).
    pub held: u32,
}

/// The arbiter's answer for one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpotGrant {
    pub job: usize,
    /// Spot instances granted this slot (≤ want, Σ ≤ avail).
    pub granted: u32,
    /// Held instances forcibly lost at slot entry — the region (or
    /// higher-priority demand) can no longer support them. Voluntary
    /// scale-downs are not counted.
    pub preempted: u32,
}

/// Water-fill `cap` units across `requests` (already paired with their
/// demands): tiers from high to low; within a tier, fair-share at the
/// highest feasible water level with the partial round going to
/// ascending job ids. Closed-form equivalent of one unit per job per
/// round in ascending job-id order until demands or capacity run out —
/// O(k log k) in the tier's member count instead of O(capacity), which
/// is what keeps 100k-unit regions arbitrable per slot. Bit-identity
/// with the historical unit loop ([`water_fill_reference`]) is
/// property-tested in `tests/fleet_properties.rs`.
pub fn water_fill(cap: u32, requests: &[SpotRequest], demands: &[u32]) -> Vec<u32> {
    debug_assert_eq!(requests.len(), demands.len());
    let mut out = vec![0u32; requests.len()];
    let mut left = cap;

    let mut tiers: Vec<Tier> = requests.iter().map(|r| r.tier).collect();
    tiers.sort();
    tiers.dedup();

    for tier in tiers.into_iter().rev() {
        if left == 0 {
            break;
        }
        let mut members: Vec<usize> = (0..requests.len())
            .filter(|&i| requests[i].tier == tier)
            .collect();
        members.sort_by_key(|&i| requests[i].job);

        let total: u64 = members.iter().map(|&i| demands[i] as u64).sum();
        if total <= left as u64 {
            // Demand fits: everyone gets exactly what they asked for.
            for &i in &members {
                out[i] = demands[i];
            }
            left -= total as u32;
            continue;
        }

        // Demand exceeds the remaining budget: find the water level L =
        // the number of complete one-unit rounds the loop would run,
        // i.e. the largest L with Σ min(dᵢ, L) ≤ budget, by walking the
        // sorted demand profile block by block.
        let budget = left as u64;
        let mut srt: Vec<u64> =
            members.iter().map(|&i| demands[i] as u64).collect();
        srt.sort_unstable();
        let n = srt.len();
        let mut used = 0u64; // Σ min(dᵢ, level) so far
        let mut level = 0u64;
        let mut idx = 0usize; // members below idx are fully satisfied
        let (level, partial) = loop {
            debug_assert!(idx < n, "total > budget ⇒ the walk stops inside");
            let d = srt[idx];
            let active = (n - idx) as u64;
            let step = d - level;
            if used + active * step <= budget {
                used += active * step;
                level = d;
                while idx < n && srt[idx] == level {
                    idx += 1;
                }
            } else {
                let extra = (budget - used) / active;
                break (level + extra, (budget - used) % active);
            }
        };
        // The partial round: one extra unit to the first `partial`
        // still-hungry members in ascending job-id order — exactly where
        // the unit loop would have stopped.
        let mut partial = partial;
        for &i in &members {
            let d = demands[i] as u64;
            let mut g = d.min(level);
            if partial > 0 && d > level {
                g += 1;
                partial -= 1;
            }
            out[i] = g as u32;
        }
        debug_assert_eq!(partial, 0, "maximal level leaves partial < hungry");
        // The tier consumed the entire remaining budget.
        left = 0;
    }
    out
}

/// The historical one-unit-per-round water-fill, kept as the executable
/// specification the arithmetic [`water_fill`] is property-tested
/// against (and benchmarked against in `fig14_fleet_100k`). O(min(cap,
/// Σ demand)) — do not call on the hot path.
pub fn water_fill_reference(
    cap: u32,
    requests: &[SpotRequest],
    demands: &[u32],
) -> Vec<u32> {
    debug_assert_eq!(requests.len(), demands.len());
    let mut out = vec![0u32; requests.len()];
    let mut left = cap;

    let mut tiers: Vec<Tier> = requests.iter().map(|r| r.tier).collect();
    tiers.sort();
    tiers.dedup();

    for tier in tiers.into_iter().rev() {
        if left == 0 {
            break;
        }
        let mut members: Vec<usize> = (0..requests.len())
            .filter(|&i| requests[i].tier == tier)
            .collect();
        members.sort_by_key(|&i| requests[i].job);
        loop {
            let mut progressed = false;
            for &i in &members {
                if left == 0 {
                    break;
                }
                if out[i] < demands[i] {
                    out[i] += 1;
                    left -= 1;
                    progressed = true;
                }
            }
            if !progressed || left == 0 {
                break;
            }
        }
    }
    out
}

/// Arbitrate one region-slot.
///
/// Each job stakes a *claim* of `max(held, want)` — defending what it
/// already runs and bidding for what it wants — and claims are
/// water-filled under the cap (tiers first, fair-share within). From a
/// job's filled claim `fill`:
///
/// - `granted = min(fill, want)` — never above the request;
/// - capacity a job claimed for retention but did not request again is
///   redistributed to still-hungry requesters in a second fill;
/// - `kept    = min(held, max(fill, granted))` — instances that survive
///   the slot; `preempted = held − kept` — a drop is forced exactly
///   when the job's share (capacity minus higher-priority and
///   fair-share claims) can no longer cover it, whether the cause is an
///   availability collapse or a higher tier's demand displacing a
///   holder. Preemption is measured against the *final* grant, not the
///   claim-phase fill: redistribution can raise a grant back to or
///   above `held`, and a job that ends the slot holding at least what
///   it held before was not preempted.
///
/// With a single requester this reduces *exactly* to the per-job
/// market: `granted = min(want, avail)`, `preempted = held − min(held,
/// avail)` — in every case, including a voluntary scale-down during an
/// availability drop.
pub fn arbitrate(avail: u32, requests: &[SpotRequest]) -> Vec<SpotGrant> {
    let claims: Vec<u32> =
        requests.iter().map(|r| r.held.max(r.want)).collect();
    let fill = water_fill(avail, requests, &claims);

    let mut granted: Vec<u32> = requests
        .iter()
        .zip(&fill)
        .map(|(r, &f)| f.min(r.want))
        .collect();
    // Redistribute capacity held-but-not-rewanted to unmet requests.
    let leftover = avail - granted.iter().sum::<u32>();
    if leftover > 0 {
        let residual: Vec<u32> = requests
            .iter()
            .zip(&granted)
            .map(|(r, &g)| r.want - g)
            .collect();
        let extra = water_fill(leftover, requests, &residual);
        for (g, e) in granted.iter_mut().zip(&extra) {
            *g += e;
        }
    }

    requests
        .iter()
        .enumerate()
        .map(|(i, r)| SpotGrant {
            job: r.job,
            granted: granted[i],
            preempted: r.held.saturating_sub(fill[i].max(granted[i])),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(job: usize, tier: Tier, want: u32, held: u32) -> SpotRequest {
        SpotRequest { job, tier, want, held }
    }

    #[test]
    fn single_tenant_matches_market_semantics() {
        // granted = min(want, avail); preempted = held - min(held, avail)
        let g = arbitrate(4, &[req(0, Tier::Normal, 10, 7)]);
        assert_eq!(g[0].granted, 4);
        assert_eq!(g[0].preempted, 3);
        let g = arbitrate(9, &[req(0, Tier::Normal, 2, 7)]);
        assert_eq!(g[0].granted, 2);
        assert_eq!(g[0].preempted, 0); // voluntary scale-down
    }

    #[test]
    fn conserves_capacity() {
        let rs = [
            req(0, Tier::High, 6, 0),
            req(1, Tier::Normal, 6, 0),
            req(2, Tier::Low, 6, 0),
        ];
        for avail in 0..=18 {
            let total: u32 =
                arbitrate(avail, &rs).iter().map(|g| g.granted).sum();
            assert!(total <= avail);
            assert_eq!(total, avail.min(18));
        }
    }

    #[test]
    fn higher_tier_served_first() {
        let g = arbitrate(
            5,
            &[req(0, Tier::Low, 4, 0), req(1, Tier::High, 4, 0)],
        );
        assert_eq!(g[1].granted, 4);
        assert_eq!(g[0].granted, 1);
    }

    #[test]
    fn fair_share_within_tier() {
        let g = arbitrate(
            5,
            &[req(0, Tier::Normal, 5, 0), req(1, Tier::Normal, 5, 0)],
        );
        // water-fill: 3/2 split, extra unit to the lower job id.
        assert_eq!(g[0].granted, 3);
        assert_eq!(g[1].granted, 2);
    }

    #[test]
    fn unneeded_capacity_flows_down() {
        let g = arbitrate(
            8,
            &[req(0, Tier::High, 2, 0), req(1, Tier::Low, 10, 0)],
        );
        assert_eq!(g[0].granted, 2);
        assert_eq!(g[1].granted, 6);
    }

    #[test]
    fn cascading_preemption_hits_low_tier_first() {
        // Fleet collectively holds 10, availability collapses to 4:
        // the high-tier job keeps all 4, everyone else is preempted.
        let g = arbitrate(
            4,
            &[
                req(0, Tier::Low, 3, 3),
                req(1, Tier::High, 4, 4),
                req(2, Tier::Normal, 3, 3),
            ],
        );
        assert_eq!(g[1].preempted, 0);
        assert_eq!(g[2].preempted, 3);
        assert_eq!(g[0].preempted, 3);
        let kept: u32 = [3u32, 4, 3]
            .iter()
            .zip(&g)
            .map(|(h, x)| h - x.preempted)
            .sum();
        assert_eq!(kept, 4); // exactly the surviving capacity
    }

    #[test]
    fn deterministic_and_order_independent_output_mapping() {
        let rs = [
            req(2, Tier::Normal, 4, 1),
            req(0, Tier::Normal, 4, 1),
            req(1, Tier::High, 4, 1),
        ];
        let a = arbitrate(6, &rs);
        let b = arbitrate(6, &rs);
        assert_eq!(a, b);
        // grants come back positionally aligned with the input slice
        assert_eq!(a[0].job, 2);
        assert_eq!(a[1].job, 0);
        assert_eq!(a[2].job, 1);
        // high tier fully served, remainder fair-shared by job id
        assert_eq!(a[2].granted, 4);
        assert_eq!(a[1].granted, 1);
        assert_eq!(a[0].granted, 1);
    }

    #[test]
    fn high_tier_demand_displacing_a_holder_counts_as_preemption() {
        // Steady avail=4: a low-tier job holds all 4; a high-tier job
        // holding nothing demands 4. The holder is forcibly stripped —
        // that is a preemption even though availability never dropped.
        let g = arbitrate(
            4,
            &[req(0, Tier::Low, 4, 4), req(1, Tier::High, 4, 0)],
        );
        assert_eq!(g[1].granted, 4);
        assert_eq!(g[0].granted, 0);
        assert_eq!(g[0].preempted, 4);
        assert_eq!(g[1].preempted, 0);
    }

    #[test]
    fn retention_claims_do_not_strand_capacity() {
        // A scales down voluntarily (held 8 → want 2) while B wants 10
        // with avail 10: B must end up with 8, not blocked by A's
        // retention claim.
        let g = arbitrate(
            10,
            &[
                req(0, Tier::Normal, 2, 8),
                req(1, Tier::Normal, 10, 0),
            ],
        );
        assert_eq!(g[0].granted, 2);
        assert_eq!(g[1].granted, 8);
        let total: u32 = g.iter().map(|x| x.granted).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn redistribution_above_fill_is_not_a_preemption() {
        // A scales down voluntarily (held 8 → want 2) while B (held 6)
        // wants 10, avail 10. The claim-phase fill splits 5/5, but
        // redistribution of A's released capacity lifts B's final grant
        // to 8 ≥ held: B ends the slot holding *more* than before and
        // must not be reported preempted (the fill-based accounting
        // wrongly charged it 1).
        let g = arbitrate(
            10,
            &[
                req(0, Tier::Normal, 2, 8),
                req(1, Tier::Normal, 10, 6),
            ],
        );
        assert_eq!(g[0].granted, 2);
        assert_eq!(g[1].granted, 8);
        assert_eq!(g[1].preempted, 0);
        // A's forced loss is unchanged: it defended 8, kept 5, chose 2.
        assert_eq!(g[0].preempted, 3);
    }

    #[test]
    fn arithmetic_water_fill_matches_reference_on_fixtures() {
        let rs = [
            req(0, Tier::High, 7, 2),
            req(3, Tier::Normal, 0, 5),
            req(1, Tier::Normal, 13, 0),
            req(2, Tier::Low, 9, 9),
            req(4, Tier::Normal, 13, 1),
        ];
        let demands: Vec<u32> =
            rs.iter().map(|r| r.held.max(r.want)).collect();
        for cap in [0, 1, 5, 12, 23, 47, 1000] {
            assert_eq!(
                water_fill(cap, &rs, &demands),
                water_fill_reference(cap, &rs, &demands),
                "cap={cap}"
            );
        }
    }

    #[test]
    fn zero_availability_preempts_everything_grants_nothing() {
        let g = arbitrate(
            0,
            &[req(0, Tier::High, 5, 2), req(1, Tier::Low, 5, 3)],
        );
        assert!(g.iter().all(|x| x.granted == 0));
        assert_eq!(g[0].preempted, 2);
        assert_eq!(g[1].preempted, 3);
    }
}
