//! Multi-region spot-market substrate: a [`RegionSet`] of independently
//! traced regional markets (each a [`SpotTrace`], reusing
//! [`crate::market::generator`]) plus the migration-cost model charged
//! when a job moves its training pool between regions (checkpoint
//! transfer + cold restart — the SkyNomad-style cross-region move).

use crate::market::generator::TraceGenerator;
use crate::market::market::MarketObs;
use crate::market::trace::SpotTrace;
use crate::sched::policy::MigrationTerms;
use crate::util::stats::argmax_total;

/// How jobs move between regions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MigrationMode {
    /// The historical reflex: a job that starves past the engine's
    /// patience flees to the observably best region. Reactive — it fires
    /// only *after* capacity has already collapsed.
    #[default]
    Starvation,
    /// Region-aware policies emit their own migration intents from the
    /// CHC subproblem (Eq. 10 with the migration term), judged on
    /// *forecasts* of every region — predictive. The starvation reflex
    /// remains the fallback for policies that are not region-aware.
    Policy,
}

/// One regional spot market: a name and its price/availability trace.
/// Availability is the *shared* regional capacity — all jobs homed in the
/// region compete for it through the capacity arbiter.
#[derive(Debug, Clone, PartialEq)]
pub struct Region {
    pub name: String,
    pub trace: SpotTrace,
}

/// Cost model for moving a job between regions: a flat monetary charge
/// (checkpoint egress + instance relaunch) and a progress factor applied
/// to the first slot in the new region (the pool restarts cold, so the
/// slot is only partially effective — analogous to the μ₁ scale-up but
/// strictly worse).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationModel {
    /// Monetary cost charged to the job at the moment it migrates.
    pub cost: f64,
    /// Effective-computation fraction of the migration slot, in [0, 1].
    pub mu: f64,
}

impl MigrationModel {
    pub fn new(cost: f64, mu: f64) -> Self {
        assert!(cost >= 0.0, "migration cost must be non-negative");
        assert!((0.0..=1.0).contains(&mu), "migration μ must be in [0,1]");
        MigrationModel { cost, mu }
    }

    /// Free, instant migration (useful in tests).
    pub fn free() -> Self {
        MigrationModel { cost: 0.0, mu: 1.0 }
    }

    /// A migration that can never pay for itself: region-aware policies
    /// treat an infinite cost as "migration disabled", which is the
    /// degenerate case that reproduces single-market trajectories
    /// bit-for-bit.
    pub fn unpayable() -> Self {
        MigrationModel { cost: f64::INFINITY, mu: 1.0 }
    }

    /// The scheduling layer's view of this model (what region-aware
    /// policies fold into the CHC subproblem).
    pub fn terms(&self) -> MigrationTerms {
        MigrationTerms { cost: self.cost, mu: self.mu }
    }
}

impl Default for MigrationModel {
    /// Calibrated to a 30-min slot: moving a LoRA checkpoint plus
    /// relaunching costs about two on-demand instance-slots and wipes
    /// half of the arrival slot.
    fn default() -> Self {
        MigrationModel { cost: 2.0, mu: 0.5 }
    }
}

/// A set of regional spot markets sharing one slot clock, plus the
/// migration model between them.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionSet {
    pub regions: Vec<Region>,
    pub migration: MigrationModel,
}

impl RegionSet {
    pub fn new(regions: Vec<Region>) -> Self {
        assert!(!regions.is_empty(), "a fleet needs at least one region");
        RegionSet { regions, migration: MigrationModel::default() }
    }

    /// Single-region set over an existing trace — the degenerate fleet
    /// that must reproduce [`crate::sched::simulate::run_episode`].
    pub fn single(trace: SpotTrace) -> Self {
        RegionSet::new(vec![Region { name: "region-0".to_string(), trace }])
    }

    /// `n` regions with independent synthetic traces from `gen`, seeded
    /// deterministically off `seed`. The mix uses `r + 1` so region 0
    /// does not collapse to the bare `seed` — callers derive other
    /// streams (job sampling, predictor noise) from the same seed, and
    /// those must stay decorrelated from every region's trace.
    pub fn generated(n: usize, gen: &TraceGenerator, seed: u64) -> Self {
        assert!(n >= 1);
        let regions = (0..n)
            .map(|r| Region {
                name: format!("region-{r}"),
                trace: gen.generate(
                    seed ^ (r as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A),
                ),
            })
            .collect();
        RegionSet::new(regions)
    }

    pub fn with_migration(mut self, m: MigrationModel) -> Self {
        self.migration = m;
        self
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    pub fn get(&self, r: usize) -> &Region {
        &self.regions[r]
    }

    /// Spot availability of region `r` at global slot `t`.
    pub fn avail(&self, r: usize, t: usize) -> u32 {
        self.regions[r].trace.avail_at(t)
    }

    /// Spot price of region `r` at global slot `t`.
    pub fn price(&self, r: usize, t: usize) -> f64 {
        self.regions[r].trace.price_at(t)
    }

    /// What a job homed in region `r` observes at global slot `t`.
    /// `local_t` is the slot index within the *job's* horizon (differs
    /// from `t` when the job arrived late), matching the episode
    /// simulator's convention that `obs.t` counts the job's own slots.
    pub fn observe(
        &self,
        r: usize,
        t: usize,
        local_t: usize,
        on_demand_price: f64,
    ) -> MarketObs {
        MarketObs {
            t: local_t,
            spot_price: self.price(r, t),
            avail: self.avail(r, t),
            on_demand_price,
        }
    }

    /// Best region to flee to at global slot `t`, judged only on the
    /// currently observable state (no future information): maximum spot
    /// availability, ties broken by lower spot price, then lower index.
    ///
    /// Total and deterministic via [`argmax_total`]: only regions at the
    /// maximum availability compete on price, a NaN price is ranked
    /// below every real price (instead of winning or losing ties by
    /// comparison-order accident), and remaining ties go to the lowest
    /// index.
    pub fn best_region(&self, t: usize) -> usize {
        let max_avail = (0..self.len())
            .map(|r| self.avail(r, t))
            .max()
            .unwrap_or(0);
        let scores: Vec<f64> = (0..self.len())
            .map(|r| {
                if self.avail(r, t) != max_avail {
                    return f64::NEG_INFINITY;
                }
                let p = self.price(r, t);
                // Eligible but price-incomparable (NaN) or infinitely
                // expensive (+∞): rank below every real price but stay
                // strictly above the ineligibility sentinel — folding
                // to −∞ would silently drop a max-availability region
                // from contention.
                if p.is_nan() {
                    f64::MIN
                } else {
                    (-p).max(f64::MIN)
                }
            })
            .collect();
        argmax_total(&scores)
    }
}

/// Shared unit-test fixture (engine + replay tests): a correlated
/// capacity shift at `shift` — region 0 ("draining", 0.30) goes 12 → 0
/// spot while region 1 ("filling", 0.35) goes 1 → 12, under a (1.0, 0.5)
/// migration model. This is the canonical predictive-migration scenario;
/// `benches/fig13_migration.rs` keeps its own richer 3-region, jittered
/// variant for the acceptance gate.
#[cfg(test)]
pub(crate) fn capacity_shift_fixture(shift: usize, slots: usize) -> RegionSet {
    let step = |hi: u32, lo: u32| -> Vec<u32> {
        (0..slots).map(|t| if t < shift { hi } else { lo }).collect()
    };
    RegionSet::new(vec![
        Region {
            name: "draining".into(),
            trace: SpotTrace::new(vec![0.3; slots], step(12, 0)),
        },
        Region {
            name: "filling".into(),
            trace: SpotTrace::new(vec![0.35; slots], step(1, 12)),
        },
    ])
    .with_migration(MigrationModel::new(1.0, 0.5))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_regions() -> RegionSet {
        RegionSet::new(vec![
            Region {
                name: "a".into(),
                trace: SpotTrace::new(vec![0.5, 0.5], vec![2, 0]),
            },
            Region {
                name: "b".into(),
                trace: SpotTrace::new(vec![0.3, 0.6], vec![2, 8]),
            },
        ])
    }

    #[test]
    fn observe_reads_region_trace_with_local_slot() {
        let rs = two_regions();
        let o = rs.observe(1, 1, 0, 1.0);
        assert_eq!(o.t, 0);
        assert_eq!(o.avail, 8);
        assert!((o.spot_price - 0.6).abs() < 1e-12);
        assert!((o.on_demand_price - 1.0).abs() < 1e-12);
    }

    #[test]
    fn best_region_prefers_availability_then_price() {
        let rs = two_regions();
        // slot 0: equal availability (2 vs 2) → cheaper region 1 wins.
        assert_eq!(rs.best_region(0), 1);
        // slot 1: region 1 has 8 vs 0 → wins on availability.
        assert_eq!(rs.best_region(1), 1);
    }

    #[test]
    fn best_region_is_total_and_deterministic() {
        // Exact availability + price ties break to the lowest index.
        let tied = RegionSet::new(vec![
            Region { name: "a".into(), trace: SpotTrace::new(vec![0.5], vec![4]) },
            Region { name: "b".into(), trace: SpotTrace::new(vec![0.5], vec![4]) },
            Region { name: "c".into(), trace: SpotTrace::new(vec![0.5], vec![4]) },
        ]);
        assert_eq!(tied.best_region(0), 0);
        // A NaN price never beats a real price on the availability tie…
        let nan_vs_real = RegionSet::new(vec![
            Region { name: "nan".into(), trace: SpotTrace::new(vec![f64::NAN], vec![4]) },
            Region { name: "real".into(), trace: SpotTrace::new(vec![0.9], vec![4]) },
        ]);
        assert_eq!(nan_vs_real.best_region(0), 1);
        // …but a NaN-priced region still wins on strictly higher
        // availability (it must not be dropped from contention).
        let nan_high = RegionSet::new(vec![
            Region { name: "real".into(), trace: SpotTrace::new(vec![0.1], vec![2]) },
            Region { name: "nan".into(), trace: SpotTrace::new(vec![f64::NAN], vec![8]) },
        ]);
        assert_eq!(nan_high.best_region(0), 1);
        // All-NaN at the max availability: lowest index, no panic.
        let all_nan = RegionSet::new(vec![
            Region { name: "a".into(), trace: SpotTrace::new(vec![f64::NAN], vec![4]) },
            Region { name: "b".into(), trace: SpotTrace::new(vec![f64::NAN], vec![4]) },
        ]);
        assert_eq!(all_nan.best_region(0), 0);
        // A +∞ price must not demote a max-availability region to the
        // ineligibility sentinel: availability still dominates price.
        let inf_high = RegionSet::new(vec![
            Region { name: "cheap".into(), trace: SpotTrace::new(vec![0.5], vec![2]) },
            Region {
                name: "inf".into(),
                trace: SpotTrace::new(vec![f64::INFINITY], vec![8]),
            },
        ]);
        assert_eq!(inf_high.best_region(0), 1);
    }

    #[test]
    fn unpayable_migration_terms_are_infinite() {
        let m = MigrationModel::unpayable();
        assert!(!m.terms().cost.is_finite());
        let t = MigrationModel::new(2.0, 0.5).terms();
        assert_eq!((t.cost, t.mu), (2.0, 0.5));
    }

    #[test]
    fn generated_regions_are_independent_and_deterministic() {
        let gen = TraceGenerator::calibrated();
        let a = RegionSet::generated(3, &gen, 7);
        let b = RegionSet::generated(3, &gen, 7);
        assert_eq!(a, b);
        assert_ne!(a.get(0).trace, a.get(1).trace);
        assert_ne!(a.get(1).trace, a.get(2).trace);
    }

    #[test]
    fn single_region_wraps_trace() {
        let tr = SpotTrace::new(vec![0.4], vec![5]);
        let rs = RegionSet::single(tr.clone());
        assert_eq!(rs.len(), 1);
        assert_eq!(rs.get(0).trace, tr);
    }

    #[test]
    #[should_panic]
    fn migration_model_rejects_bad_mu() {
        MigrationModel::new(1.0, 1.5);
    }
}
