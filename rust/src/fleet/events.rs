//! The event-driven fleet stepper: the delta-replay insight — *a slot
//! whose request set didn't change is provably identical* — promoted
//! from the counterfactual engine to the primary simulation path.
//!
//! The dense loop in [`crate::fleet::engine`] water-fills every region
//! over every job every slot, O(jobs × regions × horizon) even when
//! almost nothing changed. This stepper reorganizes the same simulation
//! around three structures:
//!
//! - **Per-region event queues.** Each region owns a slot-sorted queue
//!   of arrivals (base fleet + churn) plus staged migration hand-offs;
//!   a job exists in exactly one region's member set while active and
//!   is retired the moment it completes or its deadline expires. The
//!   per-slot cost is proportional to *active* members, not to the
//!   fleet's lifetime population — the difference between 100k churning
//!   jobs and 100k× the horizon.
//! - **Dirty-set arbitration.** [`crate::fleet::capacity::arbitrate`]
//!   is a pure function of `(avail, requests)`, and a member's request
//!   is `(job, tier, want, held)`. If a region's membership, capacity,
//!   and every member's want are unchanged since the previous slot, and
//!   the previous arbitration granted every member exactly what it held
//!   (`grant == held`, so `held` is unchanged too), then this slot's
//!   arbitration input is *identical* to the previous one — determinism
//!   forces the identical output: `grant = held`, `preempted = 0`. The
//!   stepper tracks exactly those four dirt conditions and skips the
//!   arbiter on clean slots, taking the proven answer instead. Traced
//!   runs disable the skip so the emitted [`crate::obs`] event stream
//!   is byte-identical to the dense engine's.
//! - **Struct-of-arrays job state.** The arbitration-hot per-member
//!   state (`held`, `want`) lives in flat parallel arrays per region;
//!   cold accounting (costs, decisions, the policy itself) rides behind
//!   in a `JobCore`. Request vectors are rebuilt from the hot arrays
//!   without touching the cold data.
//!
//! Regions within a slot are independent — every cross-region read
//! (observations, snapshots, forecasts) is immutable, and the only
//! cross-region *write* (a migration) is staged on the source shard and
//! reconciled sequentially between slots, exactly when a dense-booked
//! migration first becomes visible. That makes the per-slot region loop
//! embarrassingly parallel: it fans out over
//! [`crate::fleet::sweep::run_parallel_with`], and the result is
//! bit-identical for any thread count.
//!
//! Bit-identity with the dense stepper — `FleetResult`, committed
//! traces, and merged obs streams, across seeds × churn × migration
//! modes × thread counts — is enforced by
//! `tests/fleet_engine_equivalence.rs`; the 100k-job × 64-region scale
//! target is tracked by the `fig14_fleet_100k` bench.

use std::sync::Mutex;

use crate::fleet::capacity::{arbitrate, SpotRequest};
use crate::fleet::engine::{
    CommittedTrace, FleetEngine, FleetJobSpec, FleetResult, JobFinal,
};
use crate::fleet::region::MigrationMode;
use crate::fleet::sweep::run_parallel_with;
use crate::market::market::MarketObs;
use crate::obs::{Counter, Event, MigrationPhase, Recorder};
use crate::sched::policy::{
    Allocation, Policy, RegionDecision, RegionView, SlotContext,
};

/// A queued arrival: spec index plus its prebuilt policy (taken once
/// when the job is admitted).
type Arrival = (usize, Option<Box<dyn Policy>>);

/// Cold per-member state: the policy driving the job plus every
/// accounting accumulator the settlement needs. Kept out of the hot
/// arrays so arbitration never walks it.
struct JobCore {
    /// Index into the spec slice (the global job id).
    spec: usize,
    policy: Box<dyn Policy>,
    progress: f64,
    prev_total: u32,
    prev_avail: u32,
    /// Consecutive slots the job wanted spot and got none.
    starved: usize,
    /// Apply the migration μ to the next slot's progress.
    migration_mu_pending: bool,
    /// Validated migration intent from this slot's phase 1.
    intent: Option<usize>,
    /// Settlement accumulators (region/progress finalized on retire).
    fin: JobFinal,
    /// Committed per-slot requests and regions (record mode only).
    wants: Vec<Allocation>,
    regions: Vec<usize>,
}

impl JobCore {
    fn fresh(spec: usize, policy: Box<dyn Policy>, region: usize) -> JobCore {
        JobCore {
            spec,
            policy,
            progress: 0.0,
            prev_total: 0,
            prev_avail: 0,
            starved: 0,
            migration_mu_pending: false,
            intent: None,
            fin: JobFinal::fresh(region),
            wants: Vec::new(),
            regions: Vec::new(),
        }
    }

    /// Seal the core into its terminal state.
    fn retire(mut self, region: usize) -> (usize, JobFinal, Vec<Allocation>, Vec<usize>) {
        self.fin.region = region;
        self.fin.progress = self.progress;
        (self.spec, self.fin, self.wants, self.regions)
    }
}

/// One region's simulation shard: hot struct-of-arrays member state,
/// the cold cores, the arrival event queue, dirty-set tracking, and the
/// per-slot capacity history the `FleetResult` reports.
struct RegionShard {
    region: usize,
    // Hot parallel arrays — index i across all of them is one member.
    held: Vec<u32>,
    want: Vec<u32>,
    last_want: Vec<u32>,
    grant: Vec<u32>,
    preempted: Vec<u32>,
    pend: Vec<Option<(Allocation, MarketObs)>>,
    core: Vec<JobCore>,
    /// Slot-sorted arrival queue, consumed front-to-back.
    arrivals: Vec<Arrival>,
    next_arrival: usize,
    /// Re-arbitrate this slot (membership / capacity / wants / grants
    /// changed since the last arbitration-equivalent slot).
    dirty: bool,
    last_avail: u32,
    /// Σ held across members (the clean-slot granted sum).
    held_sum: u32,
    granted_hist: Vec<u32>,
    avail_hist: Vec<u32>,
    /// Outgoing migrations staged this slot: (destination, core).
    moves: Vec<(usize, JobCore)>,
    /// Members retired in this shard (completed, expired, or drained).
    done: Vec<JobCore>,
}

impl RegionShard {
    fn new(region: usize, horizon: usize) -> RegionShard {
        RegionShard {
            region,
            held: Vec::new(),
            want: Vec::new(),
            last_want: Vec::new(),
            grant: Vec::new(),
            preempted: Vec::new(),
            pend: Vec::new(),
            core: Vec::new(),
            arrivals: Vec::new(),
            next_arrival: 0,
            dirty: false,
            last_avail: 0,
            held_sum: 0,
            granted_hist: Vec::with_capacity(horizon),
            avail_hist: Vec::with_capacity(horizon),
            moves: Vec::new(),
            done: Vec::new(),
        }
    }

    /// Add a member (arrival or migration hand-off). Membership changed
    /// ⇒ the shard is dirty.
    fn admit(&mut self, core: JobCore) {
        self.held.push(0);
        self.want.push(0);
        self.last_want.push(0);
        self.grant.push(0);
        self.preempted.push(0);
        self.pend.push(None);
        self.core.push(core);
        self.dirty = true;
    }

    /// Remove member `i` from every parallel array (order within the
    /// shard is not meaningful — the arbiter keys on job ids, and the
    /// obs merge key is canonical — so `swap_remove` keeps this O(1)).
    /// Membership changed ⇒ the shard is dirty.
    fn remove(&mut self, i: usize) -> JobCore {
        self.held.swap_remove(i);
        self.want.swap_remove(i);
        self.last_want.swap_remove(i);
        self.grant.swap_remove(i);
        self.preempted.swap_remove(i);
        self.pend.swap_remove(i);
        self.dirty = true;
        self.core.swap_remove(i)
    }
}

/// Run the fleet through the event-driven stepper. Same contract as the
/// dense `FleetEngine::run_inner` with live drivers: returns the
/// settled result plus (in record mode) every job's committed trace.
pub(crate) fn run_event_driven(
    eng: &FleetEngine,
    specs: &[FleetJobSpec],
    record: bool,
    rec: &Recorder,
) -> (FleetResult, Vec<CommittedTrace>) {
    let n_regions = eng.regions.len();
    for s in specs {
        assert!(
            s.home_region < n_regions,
            "home_region {} out of range ({n_regions} regions)",
            s.home_region,
        );
    }
    let horizon = specs
        .iter()
        .map(|s| s.arrival + s.job.deadline)
        .max()
        .unwrap_or(0);

    // Prebuild every policy up front, in spec order — the exact
    // construction sequence (and forecast-pool warm-up) of the dense
    // engine's `live_drivers` — then distribute them into per-region
    // arrival queues, stable-sorted by arrival slot.
    let mut queues: Vec<Vec<Arrival>> =
        (0..n_regions).map(|_| Vec::new()).collect();
    for (j, s) in specs.iter().enumerate() {
        queues[s.home_region].push((j, Some(eng.build_policy(s))));
    }
    let mut shards: Vec<RegionShard> = (0..n_regions)
        .map(|r| RegionShard::new(r, horizon))
        .collect();
    for (r, mut q) in queues.into_iter().enumerate() {
        q.sort_by_key(|&(j, _)| specs[j].arrival);
        shards[r].arrivals = q;
    }

    let cells: Vec<Mutex<RegionShard>> =
        shards.into_iter().map(Mutex::new).collect();
    let items: Vec<usize> = (0..n_regions).collect();
    let workers = eng.threads.max(1).min(n_regions.max(1));
    let mut worker_states = vec![(); workers];

    for t in 0..horizon {
        // Parallel section: each region-slot is stepped by exactly one
        // worker (items are distinct), every cross-region access inside
        // is read-only, and the recorder's merge key is canonical — so
        // the outcome is a pure function of (engine, specs, t),
        // independent of worker count and scheduling.
        run_parallel_with(&items, &mut worker_states, |_, _, &r| {
            let mut sh = cells[r].lock().unwrap();
            step_shard(eng, specs, &mut sh, t, record, rec);
        });
        // Sequential reconcile: deliver staged migrations. A dense-
        // booked migration mutates the job's region at the end of its
        // phase 3 and is first *observed* at the next slot's phase 1 —
        // delivering between slots is the same schedule.
        for r in 0..n_regions {
            let moves = std::mem::take(&mut cells[r].lock().unwrap().moves);
            for (to, core) in moves {
                cells[to].lock().unwrap().admit(core);
            }
        }
    }

    // Drain: retire everything still alive at the horizon (dense jobs
    // simply stop being stepped there; their states settle as-is),
    // collect finals in spec order and the per-region capacity
    // histories in region order.
    let mut finals: Vec<Option<JobFinal>> =
        specs.iter().map(|_| None).collect();
    let mut committed: Vec<CommittedTrace> = specs
        .iter()
        .map(|_| CommittedTrace { wants: Vec::new(), regions: Vec::new() })
        .collect();
    let mut region_granted: Vec<Vec<u32>> = Vec::with_capacity(n_regions);
    let mut region_avail: Vec<Vec<u32>> = Vec::with_capacity(n_regions);
    for cell in cells {
        let mut sh = cell.into_inner().unwrap();
        // Arrivals the slot loop never reached (deadline-0 jobs landing
        // exactly at the horizon, or an empty horizon): they settle
        // untouched, like a dense `JobState` that never ran.
        while sh.next_arrival < sh.arrivals.len() {
            let j = sh.arrivals[sh.next_arrival].0;
            sh.next_arrival += 1;
            finals[j] = Some(JobFinal::fresh(specs[j].home_region));
        }
        while !sh.core.is_empty() {
            let core = sh.remove(0);
            sh.done.push(core);
        }
        let region = sh.region;
        for core in sh.done {
            let (j, fin, wants, regions) = core.retire(region);
            debug_assert!(finals[j].is_none(), "job {j} retired twice");
            finals[j] = Some(fin);
            committed[j] = CommittedTrace { wants, regions };
        }
        region_granted.push(sh.granted_hist);
        region_avail.push(sh.avail_hist);
    }
    let finals: Vec<JobFinal> = finals
        .into_iter()
        .map(|f| f.expect("every spec reaches a terminal state"))
        .collect();
    (
        eng.assemble_result(
            specs,
            finals,
            horizon,
            region_granted,
            region_avail,
        ),
        committed,
    )
}

/// What happens to a member at the end of its phase 3.
enum Retire {
    /// Completed (or, at the drain, horizon-expired): settle here.
    Done,
    /// Migration booked: hand the core to the destination shard.
    Move(usize),
}

/// Step one region through one global slot. Every accounting expression
/// is a verbatim copy of the dense engine's three-phase loop (that is
/// the bit-identity invariant); what differs is *when work happens* —
/// arrivals come off the event queue, retirees leave the member set,
/// and arbitration runs only on dirty slots.
fn step_shard(
    eng: &FleetEngine,
    specs: &[FleetJobSpec],
    sh: &mut RegionShard,
    t: usize,
    record: bool,
    rec: &Recorder,
) {
    let n_regions = eng.regions.len();
    let r = sh.region;
    let avail = eng.regions.avail(r, t);
    if avail != sh.last_avail {
        sh.dirty = true;
        sh.last_avail = avail;
    }

    // Event queue: admit this slot's arrivals.
    while sh.next_arrival < sh.arrivals.len()
        && specs[sh.arrivals[sh.next_arrival].0].arrival == t
    {
        let idx = sh.next_arrival;
        sh.next_arrival += 1;
        let j = sh.arrivals[idx].0;
        let policy = sh.arrivals[idx].1.take().expect("policy consumed once");
        let core = JobCore::fresh(j, policy, r);
        if specs[j].job.deadline == 0 {
            // Expired on arrival — the dense loop marks these done
            // before their first decision; they never join the members.
            sh.done.push(core);
        } else {
            sh.admit(core);
        }
    }

    // Expiry: the deadline horizon ended before this slot's decision.
    let mut i = 0;
    while i < sh.core.len() {
        let s = &specs[sh.core[i].spec];
        if t - s.arrival >= s.job.deadline {
            let core = sh.remove(i);
            sh.done.push(core);
        } else {
            i += 1;
        }
    }

    // Phase 1 — every member observes and decides (dense copy).
    let region_view_gate = eng.migration_mode == MigrationMode::Policy
        && n_regions > 1
        && eng.regions.migration.cost.is_finite();
    for i in 0..sh.core.len() {
        let j = sh.core[i].spec;
        let s = &specs[j];
        let local_t = t - s.arrival;
        let obs =
            eng.regions.observe(r, t, local_t, eng.models.on_demand_price);
        let core = &mut sh.core[i];
        let ctx = SlotContext {
            t: local_t,
            obs,
            progress: core.progress,
            prev_total: core.prev_total,
            prev_avail: core.prev_avail,
            job: &s.job,
            models: &eng.models,
        };
        let decision = if region_view_gate && core.policy.region_aware() {
            let snaps = eng.region_snapshots(s, r, t, local_t);
            let view = RegionView {
                current: r,
                candidates: &snaps,
                migration: eng.regions.migration.terms(),
            };
            core.policy.decide_region(&ctx, &view)
        } else {
            RegionDecision {
                alloc: core.policy.decide(&ctx),
                migrate_to: None,
            }
        };
        let validated = eng.validate_intent(decision.migrate_to, r, s, local_t);
        if let Some(to) = decision.migrate_to {
            rec.add(Counter::IntentsEmitted, 1);
            rec.emit(|| Event::Migration {
                round: rec.round(),
                slot: t,
                job: j,
                from: r,
                to,
                phase: MigrationPhase::Emitted,
                reason: None,
            });
            if validated.is_some() {
                rec.emit(|| Event::Migration {
                    round: rec.round(),
                    slot: t,
                    job: j,
                    from: r,
                    to,
                    phase: MigrationPhase::Validated,
                    reason: None,
                });
            } else {
                rec.add(Counter::IntentsRejected, 1);
                rec.emit(|| Event::Migration {
                    round: rec.round(),
                    slot: t,
                    job: j,
                    from: r,
                    to,
                    phase: MigrationPhase::Rejected,
                    reason: Some(
                        eng.intent_reject_reason(to, r, s, local_t),
                    ),
                });
            }
        }
        let want = decision.alloc.clamp_to_job(&s.job, obs.avail);
        core.intent = validated;
        if want.spot != sh.last_want[i] {
            sh.dirty = true;
        }
        sh.want[i] = want.spot;
        sh.pend[i] = Some((want, obs));
    }

    // Phase 2 — arbitrate if anything changed; otherwise take the
    // proven clean-slot answer. Traced runs always arbitrate so the
    // event stream matches the dense engine's byte for byte (the
    // grants still do, by the same determinism argument).
    let force = rec.is_enabled();
    let n_members = sh.core.len();
    let granted_sum: u32;
    if n_members == 0 {
        granted_sum = 0;
        sh.dirty = false;
    } else if sh.dirty || force {
        let requests: Vec<SpotRequest> = (0..n_members)
            .map(|i| SpotRequest {
                job: sh.core[i].spec,
                tier: specs[sh.core[i].spec].tier,
                want: sh.want[i],
                held: sh.held[i],
            })
            .collect();
        let grants = arbitrate(avail, &requests);
        let mut gsum = 0u32;
        let mut next_dirty = false;
        for (i, g) in grants.iter().enumerate() {
            sh.grant[i] = g.granted;
            sh.preempted[i] = g.preempted;
            gsum += g.granted;
            // A grant that changed a member's holding re-dirties the
            // next slot (its request tuple will differ).
            if g.granted != sh.held[i] {
                next_dirty = true;
            }
        }
        if rec.is_enabled() {
            rec.add(Counter::Arbitrations, 1);
            let requested: u32 = requests.iter().map(|q| q.want).sum();
            let preempted_jobs =
                grants.iter().filter(|g| g.preempted > 0).count();
            rec.emit(|| Event::Arbitration {
                round: rec.round(),
                slot: t,
                region: r,
                avail,
                requested,
                granted: gsum,
                contenders: n_members,
                preempted_jobs,
            });
            for g in &grants {
                if g.preempted > 0 {
                    rec.add(Counter::Preemptions, 1);
                    rec.emit(|| Event::Preemption {
                        round: rec.round(),
                        slot: t,
                        region: r,
                        job: g.job,
                        lost: g.preempted,
                    });
                }
            }
        }
        granted_sum = gsum;
        sh.dirty = next_dirty;
    } else {
        // Clean slot: identical arbitration input ⇒ identical output —
        // every member keeps exactly what it held, nothing is
        // preempted (see the module docs for the proof).
        for i in 0..n_members {
            sh.grant[i] = sh.held[i];
            sh.preempted[i] = 0;
        }
        granted_sum = sh.held_sum;
    }
    sh.granted_hist.push(granted_sum);
    sh.avail_hist.push(avail);

    // Phase 3 — per-member accounting (dense copy), then retirement.
    let mut retires: Vec<(usize, Retire)> = Vec::new();
    for i in 0..sh.core.len() {
        let (want, obs) = sh.pend[i].take().expect("phase 1 filled pend");
        let j = sh.core[i].spec;
        let s = &specs[j];
        let local_t = t - s.arrival;
        let spot = sh.grant[i];
        let preempted_now = sh.preempted[i];
        sh.held[i] = spot;
        let core = &mut sh.core[i];
        if record {
            core.wants.push(want);
            core.regions.push(r);
        }
        core.fin.preemptions += preempted_now as u64;
        let total = spot + want.on_demand;
        let mut mu = eng.models.reconfig.mu(core.prev_total, total);
        if core.migration_mu_pending {
            mu *= eng.regions.migration.mu;
            core.migration_mu_pending = false;
        }
        core.progress += mu * eng.models.throughput.h(total);
        if total != core.prev_total {
            core.fin.reconfigs += 1;
        }
        core.fin.spot_slots += spot;
        core.fin.on_demand_slots += want.on_demand;
        core.fin.cost += want.on_demand as f64 * obs.on_demand_price
            + spot as f64 * obs.spot_price;
        core.fin.decisions.push(Allocation::new(want.on_demand, spot));
        core.prev_total = total;
        core.prev_avail = obs.avail;

        if core.progress >= s.job.workload - 1e-9 {
            core.fin.completion_slot = Some(local_t + 1);
            retires.push((i, Retire::Done));
            continue;
        }

        // Starvation bookkeeping and migration, exactly as dense.
        if (want.spot > 0 && spot == 0)
            || (total == 0 && obs.avail < s.job.n_min)
        {
            core.starved += 1;
        } else {
            core.starved = 0;
        }
        let suppress_reflex = eng.migration_mode == MigrationMode::Policy
            && core.policy.region_aware();
        if let Some(best) = core.intent.take() {
            core.fin.cost += eng.regions.migration.cost;
            core.fin.migrations += 1;
            core.migration_mu_pending = true;
            core.starved = 0;
            rec.add(Counter::MigrationsBooked, 1);
            rec.emit(|| Event::Migration {
                round: rec.round(),
                slot: t,
                job: j,
                from: r,
                to: best,
                phase: MigrationPhase::Booked,
                reason: Some("intent"),
            });
            core.policy = eng.rebuild_policy(s, best);
            retires.push((i, Retire::Move(best)));
        } else if !suppress_reflex
            && eng.migration_patience > 0
            && n_regions > 1
            && core.starved >= eng.migration_patience
        {
            let best = eng.regions.best_region(t);
            if best != r && eng.regions.avail(best, t) > obs.avail {
                core.fin.cost += eng.regions.migration.cost;
                core.fin.migrations += 1;
                core.migration_mu_pending = true;
                core.starved = 0;
                rec.add(Counter::MigrationsBooked, 1);
                rec.emit(|| Event::Migration {
                    round: rec.round(),
                    slot: t,
                    job: j,
                    from: r,
                    to: best,
                    phase: MigrationPhase::Booked,
                    reason: Some("reflex"),
                });
                core.policy = eng.rebuild_policy(s, best);
                retires.push((i, Retire::Move(best)));
            }
        }
    }
    // Apply retirements back-to-front so pending indices stay valid
    // under swap_remove.
    for (i, action) in retires.into_iter().rev() {
        let core = sh.remove(i);
        match action {
            Retire::Done => sh.done.push(core),
            Retire::Move(to) => sh.moves.push((to, core)),
        }
    }
    // Refresh the clean-slot bookkeeping for the survivors.
    sh.held_sum = sh.held.iter().sum();
    let RegionShard { last_want, want, .. } = sh;
    last_want.copy_from_slice(want);
}
