//! Delta-replay counterfactual engine: evaluate a candidate override of
//! one fleet job in time proportional to how much the candidate
//! *differs* from the recorded run — not fleet size × horizon.
//!
//! [`FleetEngine::run_with_override`] is the reference semantics: one
//! live candidate, every other job replaying its committed trace, the
//! arbiter re-deciding every grant. But a full override re-steps the
//! whole fleet through the whole horizon for *every* candidate, even
//! though the replayed jobs merely resubmit recorded requests. A
//! selection round pays that M ≈ 112 times. [`ReplayPlan`] removes the
//! redundancy in three layers, each exact:
//!
//! 1. **Background compaction** — one pass over the [`CommittedRun`]
//!    precomputes, per slot and region, the recorded arbitration inputs
//!    and outcomes (who asked for what, holding what, granted what) plus
//!    a per-job post-slot state snapshot. Counterfactuals never re-step
//!    replayed jobs again; they read the summary.
//! 2. **Clean-slot short-circuit** — while the candidate's clamped
//!    request equals the incumbent's recorded request, every arbitration
//!    input in the fleet is identical to the recorded run's (requests
//!    are frozen, holdings follow inductively), so the water-fill +
//!    preemption cascade provably reproduces the recorded outcome: the
//!    slot costs one `decide` and an O(regions) row copy. Under
//!    policy-driven migration ([`MigrationMode::Policy`]) a clean slot
//!    additionally requires the candidate's post-slot *move* to match
//!    the recorded one — migration is part of the slot transition, and
//!    with region-aware policies it depends on the candidate's intent,
//!    not just on shared state. Divergence
//!    materializes the candidate's state from the snapshots; from then
//!    on only regions whose request set actually changed (the candidate,
//!    displaced jobs, the incumbent's vacated seat) are re-arbitrated,
//!    while untouched regions keep copying recorded rows.
//! 3. **Prefix forking** — counterfactual fleet state is memoized in a
//!    trie keyed by the candidate's post-divergence decision sequence
//!    (the clamped request *and* the slot's validated migration intent),
//!    with roots additionally partitioned by the candidate's
//!    reflex-suppression class (region-aware candidates own their moves
//!    in Policy mode, so their transitions differ from reflex-driven
//!    ones even on identical sequences). Within a class the slot
//!    transition is a deterministic function of (state, want, intent),
//!    so candidates that diverge identically (OD-heavy variants, AHAP
//!    variants sharing a commitment level until forecasts diverge) adopt
//!    each other's per-slot states instead of re-simulating them. The
//!    trie sits behind a mutex on the shared plan, so forks are reused
//!    within and across [`crate::fleet::sweep::run_parallel`] workers —
//!    and because adopted states are bit-identical to recomputed ones,
//!    results are invariant to thread count and hit pattern.
//!
//! The contract, enforced by `tests/fleet_properties.rs` and
//! `tests/fleet_integration.rs` across random fleets, the full
//! 112-policy pool, migrations, preemption cascades, and thread counts:
//! [`ReplayPlan::counterfactual`] returns a [`FleetResult`] **bit-for-bit
//! identical** to `run_with_override`. Every accounting expression below
//! mirrors the engine's slot loop exactly — same operations, same order
//! — which is what makes the equality exact rather than approximate.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::fleet::capacity::{arbitrate, SpotRequest, Tier};
use crate::fleet::engine::{CommittedRun, FleetEngine, FleetJobSpec, FleetResult, JobOutcome};
use crate::fleet::region::{MigrationMode, MigrationModel};
use crate::market::market::MarketObs;
use crate::sched::policy::{
    Allocation, Policy, RegionDecision, RegionView, SlotContext,
};
use crate::sched::pool::PolicySpec;
use crate::sched::simulate::{settle_episode, EpisodeResult};

/// How much of one counterfactual each replay tier serviced — the
/// payload of the obs `replay` event. Counting is always on (plain
/// increments in branches the loop takes anyway), so the stats cannot
/// perturb the result: [`ReplayPlan::counterfactual_stats`] returns the
/// same `FleetResult` bits as [`ReplayPlan::counterfactual`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplayStats {
    /// Slots proven identical to the recording (O(1) short-circuit),
    /// including pre-arrival slots and fully-clean early exits.
    pub clean_slots: usize,
    /// Post-divergence slots simulated locally.
    pub replayed_slots: usize,
    /// Post-divergence slots adopted from the shared fork trie.
    pub adopted_slots: usize,
    /// First divergent global slot (`None` = never diverged).
    pub diverged_at: Option<usize>,
}

/// One job's numeric simulation state — the engine's internal per-job
/// state minus the driver and the decision trace (decisions are kept
/// separately so forked states stay O(1) per slot to snapshot).
#[derive(Debug, Clone, PartialEq)]
struct Cursor {
    region: usize,
    progress: f64,
    prev_total: u32,
    prev_avail: u32,
    held: u32,
    reconfigs: u32,
    spot_slots: u32,
    on_demand_slots: u32,
    preemptions: u64,
    cost: f64,
    /// Consecutive starved slots (live candidate only; replayed jobs
    /// migrate from their recorded region sequence instead).
    starved: usize,
    migrations: u32,
    mu_pending: bool,
    completion_slot: Option<usize>,
    done: bool,
}

impl Cursor {
    fn initial(region: usize) -> Cursor {
        Cursor {
            region,
            progress: 0.0,
            prev_total: 0,
            prev_avail: 0,
            held: 0,
            reconfigs: 0,
            spot_slots: 0,
            on_demand_slots: 0,
            preemptions: 0,
            cost: 0.0,
            starved: 0,
            migrations: 0,
            mu_pending: false,
            completion_slot: None,
            done: false,
        }
    }

    /// Book a migration into `to`. Field-for-field this is both the
    /// engine's replayed-migration booking (slot-entry) and its live
    /// booking (decision-slot) — the two differ only in *when* they run,
    /// and the addition order of the surrounding cost terms is the same
    /// either way, so the totals are bit-identical.
    fn book_migration(&mut self, to: usize, mig: &MigrationModel) {
        self.cost += mig.cost;
        self.migrations += 1;
        self.held = 0;
        self.mu_pending = true;
        self.starved = 0;
        self.region = to;
    }

    /// Phase-3 accounting for one slot, mirroring the engine's
    /// expressions in the engine's order. Returns whether the job
    /// completed this slot.
    #[allow(clippy::too_many_arguments)]
    fn apply_phase3(
        &mut self,
        job: &crate::sched::job::Job,
        models: &crate::sched::policy::Models,
        mig: &MigrationModel,
        want: Allocation,
        obs: MarketObs,
        spot: u32,
        preempted: u32,
        local_t: usize,
    ) -> bool {
        self.preemptions += preempted as u64;
        self.held = spot;
        let total = spot + want.on_demand;
        let mut mu = models.reconfig.mu(self.prev_total, total);
        if self.mu_pending {
            mu *= mig.mu;
            self.mu_pending = false;
        }
        self.progress += mu * models.throughput.h(total);
        if total != self.prev_total {
            self.reconfigs += 1;
        }
        self.spot_slots += spot;
        self.on_demand_slots += want.on_demand;
        let slot_cost = want.on_demand as f64 * obs.on_demand_price
            + spot as f64 * obs.spot_price;
        self.cost += slot_cost;
        self.prev_total = total;
        self.prev_avail = obs.avail;
        if self.progress >= job.workload - 1e-9 {
            self.completion_slot = Some(local_t + 1);
            self.done = true;
            self.held = 0;
            return true;
        }
        false
    }
}

/// One job's recorded arbitration input + outcome at one region-slot.
#[derive(Debug, Clone, Copy)]
struct MemberRec {
    job: usize,
    tier: Tier,
    want_spot: u32,
    held: u32,
    granted: u32,
    preempted: u32,
}

/// The recorded arbitration of one region at one slot (members in
/// ascending job order, as the engine builds them).
#[derive(Debug, Clone, Default)]
struct RegionRow {
    members: Vec<MemberRec>,
}

/// Candidate want key for the fork trie: the clamped request plus the
/// candidate's validated migration intent for the slot (0 = none,
/// `r + 1` = move to region `r`). The intent joins the key because a
/// post-slot region change is part of the slot transition — two
/// candidates submitting the same request but moving differently reach
/// different fleet states. `INACTIVE` marks slots where the candidate
/// submits nothing (completed), after which the remaining transitions
/// are want-independent and fully shared.
type WantKey = (u32, u32, u32);
const INACTIVE: WantKey = (u32::MAX, u32::MAX, u32::MAX);

fn intent_key(intent: Option<usize>) -> u32 {
    intent.map(|r| r as u32 + 1).unwrap_or(0)
}

/// Post-slot counterfactual fleet state memoized in the fork trie: the
/// complete numeric state plus the per-slot deltas an adopter needs to
/// maintain decision traces and region rows without re-simulating.
struct ForkState {
    cand: Cursor,
    cand_decision: Option<Allocation>,
    /// The candidate live-migrated during this slot: adopters must
    /// rebuild their own policy object against this region (the numeric
    /// state is shared; the policy instance is per-candidate).
    cand_migrated: Option<usize>,
    dirty: Vec<(usize, Cursor)>,
    /// Jobs that became dirty this slot (adopters materialize their
    /// recorded decision prefix before applying `appended`).
    newly_dirty: Vec<usize>,
    /// Decisions appended to dirty jobs' traces this slot.
    appended: Vec<(usize, Allocation)>,
    /// Re-arbitrated regions' granted sums this slot, ascending by
    /// region; regions absent here copy the recorded row.
    rows: Vec<(usize, u32)>,
}

struct ForkNode {
    state: Arc<ForkState>,
    children: HashMap<WantKey, usize>,
}

#[derive(Default)]
struct ForkCache {
    /// Divergence roots keyed by (global slot, first divergent want,
    /// reflex-suppression class). The third component partitions the
    /// trie: in Policy mode the post-slot transition depends on whether
    /// the candidate's policy is region-aware (its starvation reflex is
    /// suppressed), and that bit is constant per candidate — so keying
    /// it at the root keeps every subtree's transition a pure function
    /// of (state, want, intent). Without it, a region-aware and a
    /// non-aware candidate submitting identical post-divergence
    /// sequences would adopt each other's states and silently apply (or
    /// skip) a reflex migration the full replay would not.
    roots: HashMap<(usize, WantKey, bool), usize>,
    nodes: Vec<ForkNode>,
    hits: u64,
    misses: u64,
}

/// A compacted recorded run, ready to evaluate candidate overrides of
/// `live_job` in delta time. Build once per selection round (one cheap
/// replay pass over the committed fleet), then call
/// [`counterfactual`](ReplayPlan::counterfactual) per candidate — from
/// any number of threads; the fork trie is shared behind a mutex.
pub struct ReplayPlan<'a> {
    engine: &'a FleetEngine,
    specs: &'a [FleetJobSpec],
    committed: &'a CommittedRun,
    live_job: usize,
    horizon: usize,
    n_regions: usize,
    /// `rows[t][r]` — recorded arbitration of region `r` at slot `t`.
    rows: Vec<Vec<RegionRow>>,
    /// `snaps[j][local_t]` — job `j`'s state after its local slot
    /// `local_t` (replay booking order; the learner's entry additionally
    /// carries the live starvation counter, reconstructed from the
    /// recorded series, so a diverging candidate inherits it exactly).
    snaps: Vec<Vec<Cursor>>,
    use_forks: bool,
    forks: Mutex<ForkCache>,
}

impl<'a> ReplayPlan<'a> {
    /// Compact `committed` (produced by [`FleetEngine::run_recorded`] on
    /// exactly these `specs`) for candidate overrides of `live_job`.
    pub fn new(
        engine: &'a FleetEngine,
        specs: &'a [FleetJobSpec],
        committed: &'a CommittedRun,
        live_job: usize,
    ) -> Self {
        assert_eq!(specs.len(), committed.traces.len(), "one trace per job");
        assert_eq!(specs.len(), committed.result.jobs.len());
        assert!(live_job < specs.len(), "live_job out of range");
        let n = specs.len();
        let horizon = committed.result.slots;
        let n_regions = engine.regions.len();
        let models = &engine.models;
        let mig = engine.regions.migration;

        let mut cursors: Vec<Cursor> =
            specs.iter().map(|s| Cursor::initial(s.home_region)).collect();
        let mut snaps: Vec<Vec<Cursor>> = vec![Vec::new(); n];
        let mut rows: Vec<Vec<RegionRow>> = Vec::with_capacity(horizon);
        let mut pending: Vec<Option<(Allocation, MarketObs)>> = vec![None; n];
        let mut spot_grant = vec![0u32; n];
        let mut preempted = vec![0u32; n];

        for t in 0..horizon {
            // Phase 1 — replay every job's committed choice.
            for j in 0..n {
                pending[j] = None;
                let s = &specs[j];
                let c = &mut cursors[j];
                if c.done || t < s.arrival {
                    continue;
                }
                let local_t = t - s.arrival;
                if local_t >= s.job.deadline {
                    c.done = true;
                    continue;
                }
                let tr = &committed.traces[j];
                if local_t < tr.regions.len() {
                    let region_now = tr.regions[local_t];
                    if region_now != c.region {
                        c.book_migration(region_now, &mig);
                    }
                }
                let obs = engine.regions.observe(
                    c.region,
                    t,
                    local_t,
                    models.on_demand_price,
                );
                let want = if local_t < tr.wants.len() {
                    tr.wants[local_t]
                } else {
                    Allocation::idle()
                };
                pending[j] = Some((want, obs));
            }

            // Phase 2 — record every region's arbitration.
            let mut row: Vec<RegionRow> = Vec::with_capacity(n_regions);
            for r in 0..n_regions {
                let avail = engine.regions.avail(r, t);
                let requests: Vec<SpotRequest> = (0..n)
                    .filter(|&j| pending[j].is_some() && cursors[j].region == r)
                    .map(|j| SpotRequest {
                        job: j,
                        tier: specs[j].tier,
                        want: pending[j].as_ref().unwrap().0.spot,
                        held: cursors[j].held,
                    })
                    .collect();
                let grants = arbitrate(avail, &requests);
                let mut members = Vec::with_capacity(requests.len());
                let mut granted_sum = 0u32;
                for (req, g) in requests.iter().zip(&grants) {
                    spot_grant[g.job] = g.granted;
                    preempted[g.job] = g.preempted;
                    granted_sum += g.granted;
                    members.push(MemberRec {
                        job: req.job,
                        tier: req.tier,
                        want_spot: req.want,
                        held: req.held,
                        granted: g.granted,
                        preempted: g.preempted,
                    });
                }
                debug_assert_eq!(
                    granted_sum, committed.result.region_granted[r][t],
                    "compaction diverged from the recorded run (region {r}, slot {t})"
                );
                row.push(RegionRow { members });
            }
            rows.push(row);

            // Phase 3 — accounting + snapshots.
            for j in 0..n {
                let Some((want, obs)) = pending[j].take() else {
                    continue;
                };
                let s = &specs[j];
                let local_t = t - s.arrival;
                let c = &mut cursors[j];
                let completed = c.apply_phase3(
                    &s.job,
                    models,
                    &mig,
                    want,
                    obs,
                    spot_grant[j],
                    preempted[j],
                    local_t,
                );
                // The recorded learner ran *live*: reconstruct its
                // starvation counter so a diverging candidate inherits
                // the exact state a live learner would carry. (The
                // counter's reset-on-migration lands in the next slot's
                // booking — same position the cost lands, and nothing
                // reads it in between.)
                if !completed && j == live_job {
                    let total = spot_grant[j] + want.on_demand;
                    if (want.spot > 0 && spot_grant[j] == 0)
                        || (total == 0 && obs.avail < s.job.n_min)
                    {
                        c.starved += 1;
                    } else {
                        c.starved = 0;
                    }
                }
                snaps[j].push(c.clone());
            }
        }

        for (j, jo) in committed.result.jobs.iter().enumerate() {
            debug_assert_eq!(
                snaps[j].len(),
                jo.episode.decisions.len(),
                "job {j}: snapshot count != recorded slots run"
            );
        }

        ReplayPlan {
            engine,
            specs,
            committed,
            live_job,
            horizon,
            n_regions,
            rows,
            snaps,
            use_forks: true,
            forks: Mutex::new(ForkCache::default()),
        }
    }

    /// Disable the prefix-fork trie (layers 1–2 only). Useful to isolate
    /// the layers in tests and benches; results are identical either way.
    pub fn with_forks(mut self, on: bool) -> Self {
        self.use_forks = on;
        self
    }

    /// `(hits, misses)` of the fork trie so far.
    pub fn fork_stats(&self) -> (u64, u64) {
        let c = self.forks.lock().unwrap();
        (c.hits, c.misses)
    }

    /// The recorded result with the learner relabeled as `policy` — what
    /// `run_with_override` returns when the candidate's every clamped
    /// request matches the incumbent's (identical requests arbitrate
    /// identically, slot by slot, by induction over holdings).
    fn recorded_with_label(&self, policy: &PolicySpec) -> FleetResult {
        let mut out = self.committed.result.clone();
        out.jobs[self.live_job].label = policy.label();
        out
    }

    fn push_recorded_row(&self, out: &mut [Vec<u32>], t: usize) {
        for (r, col) in out.iter_mut().enumerate() {
            col.push(self.committed.result.region_granted[r][t]);
        }
    }

    /// One live decide in the learner's slot, mirroring the engine's
    /// phase 1 exactly — including the Policy-mode region view for
    /// region-aware candidates. Returns the clamped request and the
    /// validated migration intent.
    #[allow(clippy::too_many_arguments)]
    fn decide_live(
        &self,
        policy: &mut dyn Policy,
        swapped: &FleetJobSpec,
        region: usize,
        t: usize,
        lt: usize,
        obs: MarketObs,
        prev: &Cursor,
    ) -> (Allocation, Option<usize>) {
        let models = &self.engine.models;
        let ctx = SlotContext {
            t: lt,
            obs,
            progress: prev.progress,
            prev_total: prev.prev_total,
            prev_avail: prev.prev_avail,
            job: &swapped.job,
            models,
        };
        let decision = if self.engine.migration_mode == MigrationMode::Policy
            && self.n_regions > 1
            && self.engine.regions.migration.cost.is_finite()
            && policy.region_aware()
        {
            let snaps = self.engine.region_snapshots(swapped, region, t, lt);
            let view = RegionView {
                current: region,
                candidates: &snaps,
                migration: self.engine.regions.migration.terms(),
            };
            policy.decide_region(&ctx, &view)
        } else {
            RegionDecision { alloc: policy.decide(&ctx), migrate_to: None }
        };
        (
            decision.alloc.clamp_to_job(&swapped.job, obs.avail),
            self.engine.validate_intent(decision.migrate_to, region, swapped, lt),
        )
    }

    /// The migration the candidate books after a slot, as a pure
    /// function of its post-slot state — the engine's phase-3 decision
    /// (intent primary, starvation reflex fallback). Used to extend the
    /// clean-slot check in Policy mode: a slot is only clean if the
    /// candidate's request *and* its post-slot region change both match
    /// the recording (the incumbent's move may have come from a
    /// different policy's intent, or from the reflex).
    fn live_move_after(
        &self,
        after: &Cursor,
        t: usize,
        region: usize,
        obs_avail: u32,
        suppress_reflex: bool,
        intent: Option<usize>,
    ) -> Option<usize> {
        if after.done {
            return None;
        }
        if intent.is_some() {
            return intent;
        }
        if !suppress_reflex
            && self.engine.migration_patience > 0
            && self.n_regions > 1
            && after.starved >= self.engine.migration_patience
        {
            let best = self.engine.regions.best_region(t);
            if best != region && self.engine.regions.avail(best, t) > obs_avail {
                return Some(best);
            }
        }
        None
    }

    /// Evaluate one candidate override. Bit-for-bit identical to
    /// `self.engine.run_with_override(specs, traces, live_job, policy)`.
    pub fn counterfactual(&self, policy: PolicySpec) -> FleetResult {
        self.counterfactual_stats(policy).0
    }

    /// [`counterfactual`], additionally reporting how each replay tier
    /// serviced the horizon (the obs `replay` event's payload). The
    /// stats are plain counts of branches the loop takes anyway, so the
    /// returned [`FleetResult`] is the same, bit for bit.
    ///
    /// [`counterfactual`]: ReplayPlan::counterfactual
    pub fn counterfactual_stats(
        &self,
        policy: PolicySpec,
    ) -> (FleetResult, ReplayStats) {
        let all_clean = ReplayStats {
            clean_slots: self.horizon,
            ..ReplayStats::default()
        };
        let mut stats = ReplayStats::default();
        let lr = self.live_job;
        let lspec = &self.specs[lr];
        let ltrace = &self.committed.traces[lr];
        let models = &self.engine.models;
        let regions = &self.engine.regions;
        let mig = regions.migration;
        let mut swapped = lspec.clone();
        swapped.policy = policy;
        let mut cand_policy = self.engine.build_policy(&swapped);
        let policy_mode = self.engine.migration_mode == MigrationMode::Policy;
        // Region-aware candidates own their moves in Policy mode — the
        // starvation reflex never fires for them (engine phase 3).
        let suppress_reflex = policy_mode && cand_policy.region_aware();

        let mut sync = true;
        let mut cand = Cursor::initial(lspec.home_region);
        let mut cand_decisions: Vec<Allocation> = Vec::new();
        let mut dirty: BTreeMap<usize, Cursor> = BTreeMap::new();
        let mut bg_decisions: BTreeMap<usize, Vec<Allocation>> = BTreeMap::new();
        let mut granted_out: Vec<Vec<u32>> =
            (0..self.n_regions).map(|_| Vec::with_capacity(self.horizon)).collect();
        let mut node: Option<usize> = None;

        for t in 0..self.horizon {
            // --- Candidate phase 1 -----------------------------------
            let mut cand_pending: Option<(Allocation, MarketObs)> = None;
            let mut cand_intent: Option<usize> = None;
            if sync {
                if t < lspec.arrival {
                    stats.clean_slots += 1;
                    self.push_recorded_row(&mut granted_out, t);
                    continue;
                }
                let lt = t - lspec.arrival;
                if lt >= ltrace.wants.len() {
                    // The recorded learner is done and nothing diverged:
                    // the counterfactual *is* the recorded run.
                    return (self.recorded_with_label(&policy), all_clean);
                }
                let region = ltrace.regions[lt];
                let obs =
                    regions.observe(region, t, lt, models.on_demand_price);
                let prev = if lt == 0 {
                    Cursor::initial(lspec.home_region)
                } else {
                    self.snaps[lr][lt - 1].clone()
                };
                let (want, intent) = self.decide_live(
                    cand_policy.as_mut(),
                    &swapped,
                    region,
                    t,
                    lt,
                    obs,
                    &prev,
                );
                // The recorded learner's post-slot region change (its
                // migration, whatever drove it). A move booked at the
                // learner's *last* recorded slot never shows up in
                // `regions` — the job is done at the next slot entry —
                // but it was charged (cost, migration count), so
                // `final_region` is the authority there: a candidate
                // that would not make that move must diverge, or it
                // would inherit the booking via the recorded result.
                let rec_move = if lt + 1 < ltrace.regions.len() {
                    let next = ltrace.regions[lt + 1];
                    (next != region).then_some(next)
                } else {
                    let last = self.committed.result.jobs[lr].final_region;
                    (last != region).then_some(last)
                };
                // Clean requires matching requests — and, in Policy
                // mode, a matching post-slot move: migration is part of
                // the slot transition and now depends on the policy
                // (its intent, or whether the reflex drives it), not
                // just on shared state. With matching wants the
                // candidate's post-slot state equals the snapshot, so
                // its move is a pure function of that state + intent.
                let clean = want == ltrace.wants[lt]
                    && (!policy_mode
                        || self.live_move_after(
                            &self.snaps[lr][lt],
                            t,
                            region,
                            obs.avail,
                            suppress_reflex,
                            intent,
                        ) == rec_move);
                if clean {
                    // Clean slot: every arbitration input equals the
                    // recorded run's, so the outcome does too — O(1).
                    stats.clean_slots += 1;
                    self.push_recorded_row(&mut granted_out, t);
                    // Mirror the live learner's post-migration replan
                    // (the engine's shared rebuild path: cold private
                    // predictors in Starvation mode, warm cross-region
                    // cache handles in Policy mode).
                    if let Some(to) = rec_move {
                        cand_policy = self.engine.rebuild_policy(&swapped, to);
                    }
                    continue;
                }
                // First divergent slot: materialize the candidate from
                // the snapshots (booking the slot-entry migration the
                // snapshot hasn't applied yet) and fall through.
                sync = false;
                stats.diverged_at = Some(t);
                cand = prev;
                if lt > 0 && region != cand.region {
                    cand.book_migration(region, &mig);
                }
                cand_decisions = self.committed.result.jobs[lr]
                    .episode
                    .decisions[..lt]
                    .to_vec();
                cand_pending = Some((want, obs));
                cand_intent = intent;
            } else if !cand.done && t >= lspec.arrival {
                let lt = t - lspec.arrival;
                if lt >= lspec.job.deadline {
                    cand.done = true;
                } else {
                    let obs = regions.observe(
                        cand.region,
                        t,
                        lt,
                        models.on_demand_price,
                    );
                    let region_now = cand.region;
                    let (want, intent) = self.decide_live(
                        cand_policy.as_mut(),
                        &swapped,
                        region_now,
                        t,
                        lt,
                        obs,
                        &cand,
                    );
                    cand_pending = Some((want, obs));
                    cand_intent = intent;
                }
            }

            // --- Fork adoption ---------------------------------------
            let key: WantKey = match &cand_pending {
                Some((w, _)) => (w.on_demand, w.spot, intent_key(cand_intent)),
                None => INACTIVE,
            };
            if self.use_forks {
                let adopted = {
                    let mut cache = self.forks.lock().unwrap();
                    let child = match node {
                        Some(nid) => cache.nodes[nid].children.get(&key).copied(),
                        None => cache
                            .roots
                            .get(&(t, key, suppress_reflex))
                            .copied(),
                    };
                    if child.is_some() {
                        cache.hits += 1;
                    }
                    child.map(|cid| (cid, cache.nodes[cid].state.clone()))
                };
                if let Some((cid, st)) = adopted {
                    stats.adopted_slots += 1;
                    self.adopt(
                        &st,
                        t,
                        &mut cand,
                        &mut dirty,
                        &mut bg_decisions,
                        &mut cand_decisions,
                        &mut granted_out,
                    );
                    if let Some(r) = st.cand_migrated {
                        cand_policy = self.engine.rebuild_policy(&swapped, r);
                    }
                    node = Some(cid);
                    continue;
                }
            }

            // --- Simulate the slot locally ---------------------------
            stats.replayed_slots += 1;
            let (state, cand_migrated) = self.step_diverged(
                t,
                &mut cand,
                cand_pending,
                cand_intent,
                suppress_reflex,
                &mut dirty,
                &mut bg_decisions,
                &mut cand_decisions,
                &mut granted_out,
            );
            if let Some(r) = cand_migrated {
                cand_policy = self.engine.rebuild_policy(&swapped, r);
            }
            if self.use_forks {
                node =
                    Some(self.insert_fork(node, t, key, suppress_reflex, state));
            }
        }

        if sync {
            // Never diverged through the whole horizon.
            return (self.recorded_with_label(&policy), all_clean);
        }

        // --- Assembly (mirrors the engine's settlement) --------------
        let mut jobs: Vec<JobOutcome> = Vec::with_capacity(self.specs.len());
        for (j, s) in self.specs.iter().enumerate() {
            if j == lr {
                let decisions = std::mem::take(&mut cand_decisions);
                jobs.push(settle_outcome(
                    s,
                    models,
                    &cand,
                    decisions,
                    policy.label(),
                ));
            } else if let Some(c) = dirty.get(&j) {
                let decisions = bg_decisions.remove(&j).unwrap();
                jobs.push(settle_outcome(s, models, c, decisions, s.policy.label()));
            } else {
                jobs.push(self.committed.result.jobs[j].clone());
            }
        }

        let region_avail = self.committed.result.region_avail.clone();
        let n = jobs.len().max(1) as f64;
        let total_utility = jobs.iter().map(|j| j.episode.utility).sum();
        let total_value = jobs.iter().map(|j| j.episode.value).sum();
        let total_cost = jobs.iter().map(|j| j.episode.cost).sum();
        let on_time_rate =
            jobs.iter().filter(|j| j.episode.on_time).count() as f64 / n;
        let total_preemptions =
            jobs.iter().map(|j| j.episode.preemptions).sum();
        let total_migrations = jobs.iter().map(|j| j.migrations).sum();
        let region_utilization = (0..self.n_regions)
            .map(|r| {
                let mut used = 0u64;
                let mut cap = 0u64;
                for (g, a) in granted_out[r].iter().zip(&region_avail[r]) {
                    if *a > 0 {
                        used += *g as u64;
                        cap += *a as u64;
                    }
                }
                if cap == 0 {
                    0.0
                } else {
                    used as f64 / cap as f64
                }
            })
            .collect();

        (
            FleetResult {
                jobs,
                slots: self.horizon,
                total_utility,
                total_value,
                total_cost,
                on_time_rate,
                total_preemptions,
                total_migrations,
                region_utilization,
                region_granted: granted_out,
                region_avail,
            },
            stats,
        )
    }

    /// Apply a memoized fork state: replace the numeric state wholesale,
    /// extend the decision traces with this slot's deltas, and emit the
    /// slot's region rows.
    #[allow(clippy::too_many_arguments)]
    fn adopt(
        &self,
        st: &ForkState,
        t: usize,
        cand: &mut Cursor,
        dirty: &mut BTreeMap<usize, Cursor>,
        bg_decisions: &mut BTreeMap<usize, Vec<Allocation>>,
        cand_decisions: &mut Vec<Allocation>,
        granted_out: &mut [Vec<u32>],
    ) {
        *cand = st.cand.clone();
        *dirty = st.dirty.iter().cloned().collect();
        for &j in &st.newly_dirty {
            let lt = t - self.specs[j].arrival;
            bg_decisions.insert(
                j,
                self.committed.result.jobs[j].episode.decisions[..lt].to_vec(),
            );
        }
        for (j, d) in &st.appended {
            bg_decisions.get_mut(j).unwrap().push(*d);
        }
        if let Some(d) = st.cand_decision {
            cand_decisions.push(d);
        }
        let mut over = st.rows.iter().peekable();
        for (r, col) in granted_out.iter_mut().enumerate() {
            match over.peek() {
                Some(&&(rr, g)) if rr == r => {
                    col.push(g);
                    over.next();
                }
                _ => col.push(self.committed.result.region_granted[r][t]),
            }
        }
    }

    /// Insert the state computed for `(parent, key)`, returning its node
    /// id. If another worker raced us to the same transition its state
    /// is bit-identical by construction, so either `Arc` serves.
    fn insert_fork(
        &self,
        parent: Option<usize>,
        t: usize,
        key: WantKey,
        suppress_reflex: bool,
        state: Arc<ForkState>,
    ) -> usize {
        let mut cache = self.forks.lock().unwrap();
        let existing = match parent {
            Some(p) => cache.nodes[p].children.get(&key).copied(),
            None => cache.roots.get(&(t, key, suppress_reflex)).copied(),
        };
        if let Some(id) = existing {
            return id;
        }
        cache.misses += 1;
        let id = cache.nodes.len();
        cache.nodes.push(ForkNode { state, children: HashMap::new() });
        match parent {
            Some(p) => {
                cache.nodes[p].children.insert(key, id);
            }
            None => {
                cache.roots.insert((t, key, suppress_reflex), id);
            }
        }
        id
    }

    /// Simulate one post-divergence slot: replay dirty jobs' committed
    /// choices, re-arbitrate only the regions whose request set differs
    /// from the recorded run, copy every other region's recorded row,
    /// and account exactly as the engine's phase 3 (the candidate's
    /// validated migration intent booked first, the starvation reflex as
    /// the fallback unless suppressed for a region-aware candidate).
    /// Returns the fork state for the trie plus the candidate's
    /// live-migration target.
    #[allow(clippy::too_many_arguments)]
    fn step_diverged(
        &self,
        t: usize,
        cand: &mut Cursor,
        cand_pending: Option<(Allocation, MarketObs)>,
        cand_intent: Option<usize>,
        suppress_reflex: bool,
        dirty: &mut BTreeMap<usize, Cursor>,
        bg_decisions: &mut BTreeMap<usize, Vec<Allocation>>,
        cand_decisions: &mut Vec<Allocation>,
        granted_out: &mut [Vec<u32>],
    ) -> (Arc<ForkState>, Option<usize>) {
        let lr = self.live_job;
        let models = &self.engine.models;
        let regions = &self.engine.regions;
        let mig = regions.migration;

        // Phase 1 — dirty background jobs replay their committed choice.
        let mut pend: Vec<(usize, Allocation, MarketObs, usize)> = Vec::new();
        for (&j, c) in dirty.iter_mut() {
            let s = &self.specs[j];
            if c.done || t < s.arrival {
                continue;
            }
            let lt = t - s.arrival;
            if lt >= s.job.deadline {
                c.done = true;
                continue;
            }
            let tr = &self.committed.traces[j];
            if lt < tr.regions.len() {
                let region_now = tr.regions[lt];
                if region_now != c.region {
                    c.book_migration(region_now, &mig);
                }
            }
            let obs =
                regions.observe(c.region, t, lt, models.on_demand_price);
            let want = if lt < tr.wants.len() {
                tr.wants[lt]
            } else {
                Allocation::idle()
            };
            pend.push((j, want, obs, c.region));
        }

        // A region's arbitration differs from the recorded run's exactly
        // when its request set does: the candidate or a dirty job sits
        // there now, or the recorded learner / a dirty job sat there in
        // the recorded run (their recorded entry is vacated or stale).
        let mut affected = vec![false; self.n_regions];
        if cand_pending.is_some() {
            affected[cand.region] = true;
        }
        for &(_, _, _, r) in &pend {
            affected[r] = true;
        }
        for r in 0..self.n_regions {
            if !affected[r]
                && self.rows[t][r]
                    .members
                    .iter()
                    .any(|m| m.job == lr || dirty.contains_key(&m.job))
            {
                affected[r] = true;
            }
        }

        // Phase 2 — arbitrate affected regions; copy the rest.
        let mut grants_of: HashMap<usize, (u32, u32)> = HashMap::new();
        let mut newly: Vec<(usize, Allocation, MarketObs, u32, u32)> = Vec::new();
        let mut fork_rows: Vec<(usize, u32)> = Vec::new();
        for r in 0..self.n_regions {
            if !affected[r] {
                granted_out[r].push(self.committed.result.region_granted[r][t]);
                continue;
            }
            let avail = regions.avail(r, t);
            // Merge (ascending job id): recorded still-synced members,
            // dirty jobs homed here now, and the candidate.
            let mut extras: Vec<SpotRequest> = Vec::new();
            for &(j, want, _, reg) in &pend {
                if reg == r {
                    extras.push(SpotRequest {
                        job: j,
                        tier: self.specs[j].tier,
                        want: want.spot,
                        held: dirty[&j].held,
                    });
                }
            }
            if let Some((w, _)) = &cand_pending {
                if cand.region == r {
                    extras.push(SpotRequest {
                        job: lr,
                        tier: self.specs[lr].tier,
                        want: w.spot,
                        held: cand.held,
                    });
                }
            }
            extras.sort_by_key(|q| q.job);
            let mut requests: Vec<SpotRequest> = Vec::new();
            let mut rec_out: Vec<Option<(u32, u32)>> = Vec::new();
            let mut ei = 0;
            for m in &self.rows[t][r].members {
                if m.job == lr || dirty.contains_key(&m.job) {
                    continue;
                }
                while ei < extras.len() && extras[ei].job < m.job {
                    requests.push(extras[ei]);
                    rec_out.push(None);
                    ei += 1;
                }
                requests.push(SpotRequest {
                    job: m.job,
                    tier: m.tier,
                    want: m.want_spot,
                    held: m.held,
                });
                rec_out.push(Some((m.granted, m.preempted)));
            }
            while ei < extras.len() {
                requests.push(extras[ei]);
                rec_out.push(None);
                ei += 1;
            }

            let grants = arbitrate(avail, &requests);
            let mut granted_sum = 0u32;
            for g in &grants {
                granted_sum += g.granted;
            }
            granted_out[r].push(granted_sum);
            fork_rows.push((r, granted_sum));

            for (g, rec) in grants.iter().zip(&rec_out) {
                match rec {
                    None => {
                        // candidate or already-dirty job
                        grants_of.insert(g.job, (g.granted, g.preempted));
                    }
                    Some((rg, rp)) => {
                        if g.granted == *rg && g.preempted == *rp {
                            continue; // outcome unchanged: stays synced
                        }
                        // Newly displaced: materialize from snapshots.
                        let s = &self.specs[g.job];
                        let lt = t - s.arrival;
                        let mut c = if lt == 0 {
                            Cursor::initial(s.home_region)
                        } else {
                            self.snaps[g.job][lt - 1].clone()
                        };
                        let tr = &self.committed.traces[g.job];
                        if lt > 0
                            && lt < tr.regions.len()
                            && tr.regions[lt] != c.region
                        {
                            c.book_migration(tr.regions[lt], &mig);
                        }
                        debug_assert_eq!(c.region, r);
                        let want = if lt < tr.wants.len() {
                            tr.wants[lt]
                        } else {
                            Allocation::idle()
                        };
                        let obs = regions.observe(
                            r,
                            t,
                            lt,
                            models.on_demand_price,
                        );
                        newly.push((g.job, want, obs, g.granted, g.preempted));
                        dirty.insert(g.job, c);
                        bg_decisions.insert(
                            g.job,
                            self.committed.result.jobs[g.job].episode.decisions
                                [..lt]
                                .to_vec(),
                        );
                    }
                }
            }
        }

        // Phase 3 — candidate accounting (with the engine's live
        // starvation/migration logic), then dirty-job accounting.
        let mut cand_migrated = None;
        let mut cand_decision = None;
        if let Some((want, obs)) = cand_pending {
            let (sp, pe) = grants_of[&lr];
            let lt = t - self.specs[lr].arrival;
            let completed = cand.apply_phase3(
                &self.specs[lr].job,
                models,
                &mig,
                want,
                obs,
                sp,
                pe,
                lt,
            );
            let d = Allocation::new(want.on_demand, sp);
            cand_decisions.push(d);
            cand_decision = Some(d);
            if !completed {
                let total = sp + want.on_demand;
                if (want.spot > 0 && sp == 0)
                    || (total == 0 && obs.avail < self.specs[lr].job.n_min)
                {
                    cand.starved += 1;
                } else {
                    cand.starved = 0;
                }
                if let Some(best) = cand_intent {
                    // Policy-emitted move (already validated at decide
                    // time) — booked exactly like the engine's phase 3.
                    cand.book_migration(best, &mig);
                    cand_migrated = Some(best);
                } else if !suppress_reflex
                    && self.engine.migration_patience > 0
                    && self.n_regions > 1
                    && cand.starved >= self.engine.migration_patience
                {
                    let best = regions.best_region(t);
                    if best != cand.region
                        && regions.avail(best, t) > obs.avail
                    {
                        cand.book_migration(best, &mig);
                        cand_migrated = Some(best);
                    }
                }
            }
        }

        let mut appended: Vec<(usize, Allocation)> = Vec::new();
        for (j, want, obs, _) in pend {
            let (sp, pe) = grants_of[&j];
            let c = dirty.get_mut(&j).unwrap();
            let lt = t - self.specs[j].arrival;
            c.apply_phase3(&self.specs[j].job, models, &mig, want, obs, sp, pe, lt);
            let d = Allocation::new(want.on_demand, sp);
            bg_decisions.get_mut(&j).unwrap().push(d);
            appended.push((j, d));
        }
        let mut newly_dirty_ids = Vec::with_capacity(newly.len());
        for (j, want, obs, sp, pe) in newly {
            let c = dirty.get_mut(&j).unwrap();
            let lt = t - self.specs[j].arrival;
            c.apply_phase3(&self.specs[j].job, models, &mig, want, obs, sp, pe, lt);
            let d = Allocation::new(want.on_demand, sp);
            bg_decisions.get_mut(&j).unwrap().push(d);
            appended.push((j, d));
            newly_dirty_ids.push(j);
        }

        let state = Arc::new(ForkState {
            cand: cand.clone(),
            cand_decision,
            cand_migrated,
            dirty: dirty.iter().map(|(&j, c)| (j, c.clone())).collect(),
            newly_dirty: newly_dirty_ids,
            appended,
            rows: fork_rows,
        });
        (state, cand_migrated)
    }
}

/// Settle one job from its final cursor — the engine's end-of-horizon
/// settlement, expression for expression.
fn settle_outcome(
    s: &FleetJobSpec,
    models: &crate::sched::policy::Models,
    st: &Cursor,
    decisions: Vec<Allocation>,
    label: String,
) -> JobOutcome {
    let slots_run = decisions.len();
    let progress_at_deadline = st.progress.min(s.job.workload);
    let (value, total_cost, completion) = settle_episode(
        &s.job,
        models,
        st.progress,
        slots_run,
        st.cost,
        st.completion_slot,
    );
    JobOutcome {
        label,
        tier: s.tier,
        home_region: s.home_region,
        final_region: st.region,
        migrations: st.migrations,
        episode: EpisodeResult {
            utility: value - total_cost,
            value,
            cost: total_cost,
            completion_slot: completion,
            on_time: completion <= s.job.deadline,
            progress_at_deadline,
            decisions,
            spot_slots: st.spot_slots,
            on_demand_slots: st.on_demand_slots,
            preemptions: st.preemptions,
            reconfigs: st.reconfigs,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::region::{MigrationMode, MigrationModel, Region, RegionSet};
    use crate::forecast::noise::NoiseSpec;
    use crate::market::generator::TraceGenerator;
    use crate::market::trace::SpotTrace;
    use crate::sched::job::Job;
    use crate::sched::policy::Models;
    use crate::sched::pool::PredictorKind;

    fn job() -> Job {
        Job { workload: 80.0, deadline: 10, n_min: 1, n_max: 12, value: 120.0, gamma: 1.5 }
    }

    fn flat_trace(price: f64, avail: u32, slots: usize) -> SpotTrace {
        SpotTrace::new(vec![price; slots], vec![avail; slots])
    }

    fn contended_fleet() -> (FleetEngine, Vec<FleetJobSpec>) {
        let engine = FleetEngine::new(
            Models::paper_default(),
            RegionSet::single(flat_trace(0.3, 6, 24)),
        );
        let specs = vec![
            FleetJobSpec::new(job(), PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::High),
            FleetJobSpec::new(job(), PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::Low),
        ];
        (engine, specs)
    }

    #[test]
    fn incumbent_candidate_reproduces_the_recorded_run() {
        let (engine, specs) = contended_fleet();
        let rec = engine.run_recorded(&specs);
        for live in 0..specs.len() {
            let plan = ReplayPlan::new(&engine, &specs, &rec, live);
            let got = plan.counterfactual(specs[live].policy);
            let want = engine.run_with_override(
                &specs,
                &rec.traces,
                live,
                specs[live].policy,
            );
            assert_eq!(got, want, "identity broke for live job {live}");
            assert_eq!(got, rec.result);
            // The clean path never touches the trie.
            assert_eq!(plan.fork_stats(), (0, 0));
        }
    }

    #[test]
    fn replay_stats_partition_the_horizon_without_perturbing_results() {
        let (engine, specs) = contended_fleet();
        let rec = engine.run_recorded(&specs);
        let plan = ReplayPlan::new(&engine, &specs, &rec, 0);
        // Identity candidate: never diverges — all clean.
        let (same, st) = plan.counterfactual_stats(specs[0].policy);
        assert_eq!(same, rec.result);
        assert_eq!(st.clean_slots, rec.result.slots);
        assert_eq!(st.replayed_slots + st.adopted_slots, 0);
        assert_eq!(st.diverged_at, None);
        // Diverging candidate: tiers partition the horizon exactly, and
        // the result matches the plain counterfactual bit for bit.
        let (got, st) = plan.counterfactual_stats(PolicySpec::OdOnly);
        assert_eq!(got, plan.counterfactual(PolicySpec::OdOnly));
        assert_eq!(
            st.clean_slots + st.replayed_slots + st.adopted_slots,
            rec.result.slots
        );
        let div = st.diverged_at.expect("OD-Only must diverge from MSU");
        assert_eq!(st.clean_slots, div);
        assert!(st.replayed_slots > 0);
    }

    #[test]
    fn diverging_candidate_matches_run_with_override() {
        // Swapping the high-tier MSU for OD-Only frees the region: the
        // replayed low-tier job's grants, preemptions, and progress all
        // change, and the delta path must track every bit of it.
        let (engine, specs) = contended_fleet();
        let rec = engine.run_recorded(&specs);
        let plan = ReplayPlan::new(&engine, &specs, &rec, 0);
        for cand in [
            PolicySpec::OdOnly,
            PolicySpec::UniformProgress,
            PolicySpec::Ahanp { sigma: 0.5 },
            PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
        ] {
            let want = engine.run_with_override(&specs, &rec.traces, 0, cand);
            assert_eq!(
                plan.counterfactual(cand),
                want,
                "delta != full for {}",
                cand.label()
            );
            assert_ne!(want, rec.result, "candidate should actually diverge");
        }
    }

    #[test]
    fn delta_matches_override_across_recorded_and_live_migrations() {
        // Background job 0 migrates in the recorded run (dead home
        // region); candidates in job 1's slot may migrate live. Both
        // paths must reproduce run_with_override exactly.
        let j = job();
        let dead = flat_trace(0.5, 0, 16);
        let rich = flat_trace(0.4, 12, 16);
        let regions = RegionSet::new(vec![
            Region { name: "dead".into(), trace: dead },
            Region { name: "rich".into(), trace: rich },
        ])
        .with_migration(MigrationModel::new(3.0, 0.5));
        let engine = FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(2);
        let specs = vec![
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle),
            FleetJobSpec::new(j, PolicySpec::Msu, PredictorKind::Oracle),
        ];
        let rec = engine.run_recorded(&specs);
        assert!(rec.result.jobs[0].migrations >= 1, "scenario lost its migration");
        let plan = ReplayPlan::new(&engine, &specs, &rec, 1);
        for cand in [
            PolicySpec::Msu,
            PolicySpec::OdOnly,
            PolicySpec::UniformProgress,
            PolicySpec::Ahap { omega: 2, v: 1, sigma: 0.5 },
        ] {
            let want = engine.run_with_override(&specs, &rec.traces, 1, cand);
            assert_eq!(
                plan.counterfactual(cand),
                want,
                "migration case: delta != full for {}",
                cand.label()
            );
        }
    }

    #[test]
    fn noisy_predictor_candidates_match_override() {
        // Stateful predictors (RNG streams) exercise the in-sync decide
        // path: the candidate's policy must see exactly the observation
        // sequence a live learner would.
        let trace = TraceGenerator::calibrated().generate(19).slice_from(45);
        let engine =
            FleetEngine::new(Models::paper_default(), RegionSet::single(trace));
        let specs = vec![
            FleetJobSpec::new(job(), PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::High),
            FleetJobSpec::new(
                job(),
                PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
                PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.2)),
            )
            .with_seed(41)
            .with_tier(Tier::Low),
        ];
        let rec = engine.run_recorded(&specs);
        let plan = ReplayPlan::new(&engine, &specs, &rec, 1);
        for cand in [
            PolicySpec::Ahap { omega: 5, v: 2, sigma: 0.9 },
            PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
            PolicySpec::Ahanp { sigma: 0.3 },
        ] {
            let want = engine.run_with_override(&specs, &rec.traces, 1, cand);
            assert_eq!(plan.counterfactual(cand), want, "{}", cand.label());
        }
    }

    #[test]
    fn forks_are_shared_between_identical_divergence_paths() {
        let (engine, specs) = contended_fleet();
        let rec = engine.run_recorded(&specs);
        let plan = ReplayPlan::new(&engine, &specs, &rec, 0);
        let first = plan.counterfactual(PolicySpec::OdOnly);
        let (h0, m0) = plan.fork_stats();
        assert!(m0 > 0, "a diverging candidate must populate the trie");
        // Same candidate again: the whole post-divergence path is a hit.
        let second = plan.counterfactual(PolicySpec::OdOnly);
        let (h1, m1) = plan.fork_stats();
        assert_eq!(first, second);
        assert_eq!(m1, m0, "no new nodes on a fully shared path");
        assert!(h1 > h0, "second run should adopt the memoized states");
        // And forks change nothing but the cost.
        let no_forks =
            ReplayPlan::new(&engine, &specs, &rec, 0).with_forks(false);
        assert_eq!(no_forks.counterfactual(PolicySpec::OdOnly), first);
    }

    /// Capacity shifting between regions mid-horizon (the predictive-
    /// migration scenario): region 0 drains at slot 6, region 1 fills.
    fn shifting_engine(mode: MigrationMode) -> FleetEngine {
        let regions = crate::fleet::region::capacity_shift_fixture(6, 16);
        FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(2)
            .with_migration_mode(mode)
    }

    #[test]
    fn policy_mode_candidates_match_override_including_intent_migrations() {
        // Policy-driven migration in the learner's slot: region-aware
        // candidates emit intents (which join the fork key), non-aware
        // ones keep the reflex — every one must reproduce
        // run_with_override bit-for-bit, and the incumbent identity must
        // still collapse to the recorded result.
        let engine = shifting_engine(MigrationMode::Policy);
        let big = Job {
            workload: 120.0,
            deadline: 16,
            n_min: 1,
            n_max: 12,
            value: 200.0,
            gamma: 1.5,
        };
        let incumbent = PolicySpec::Ahap { omega: 5, v: 1, sigma: 0.7 };
        let specs = vec![
            FleetJobSpec::new(big, incumbent, PredictorKind::Oracle),
            FleetJobSpec::new(job(), PolicySpec::Msu, PredictorKind::Oracle)
                .with_tier(Tier::Low),
        ];
        let rec = engine.run_recorded(&specs);
        assert!(
            rec.result.jobs[0].migrations >= 1,
            "scenario lost its predictive migration: {:?}",
            rec.result.jobs[0]
        );
        let plan = ReplayPlan::new(&engine, &specs, &rec, 0);
        assert_eq!(plan.counterfactual(incumbent), rec.result);
        for cand in [
            PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.5 },
            PolicySpec::Ahap { omega: 1, v: 1, sigma: 0.9 },
            PolicySpec::Msu,
            PolicySpec::OdOnly,
            PolicySpec::Ahanp { sigma: 0.5 },
        ] {
            let want = engine.run_with_override(&specs, &rec.traces, 0, cand);
            assert_eq!(
                plan.counterfactual(cand),
                want,
                "policy-mode delta != full for {}",
                cand.label()
            );
        }
    }

    #[test]
    fn policy_mode_move_mismatch_breaks_the_clean_slot() {
        // Incumbent MSU starves in the draining region and migrates by
        // reflex; an AHAP candidate may submit the *same requests* early
        // on yet move at a different slot (or not at all) — the clean
        // check must compare moves, not just wants, or the counterfactual
        // would silently keep the recorded region sequence.
        let engine = shifting_engine(MigrationMode::Policy);
        let specs = vec![
            FleetJobSpec::new(job(), PolicySpec::Msu, PredictorKind::Oracle),
            FleetJobSpec::new(job(), PolicySpec::Msu, PredictorKind::Oracle)
                .in_region(1)
                .with_tier(Tier::Low),
        ];
        let rec = engine.run_recorded(&specs);
        let plan = ReplayPlan::new(&engine, &specs, &rec, 0);
        for cand in [
            PolicySpec::Ahap { omega: 5, v: 1, sigma: 0.7 },
            PolicySpec::Ahap { omega: 2, v: 2, sigma: 0.3 },
            PolicySpec::UniformProgress,
        ] {
            let want = engine.run_with_override(&specs, &rec.traces, 0, cand);
            assert_eq!(
                plan.counterfactual(cand),
                want,
                "move-mismatch case: delta != full for {}",
                cand.label()
            );
        }
    }

    #[test]
    fn staggered_arrivals_and_three_regions_match_override() {
        let gen = TraceGenerator::calibrated();
        let regions = RegionSet::new(vec![
            Region { name: "a".into(), trace: gen.generate(61).slice_from(20) },
            Region { name: "b".into(), trace: gen.generate(62).slice_from(30) },
            Region { name: "c".into(), trace: gen.generate(63).slice_from(40) },
        ])
        .with_migration(MigrationModel::new(2.0, 0.5));
        let engine = FleetEngine::new(Models::paper_default(), regions)
            .with_migration_patience(2);
        let mk = |p, r: usize, a: usize, tier| {
            FleetJobSpec::new(job(), p, PredictorKind::Oracle)
                .in_region(r)
                .arriving_at(a)
                .with_tier(tier)
        };
        let specs = vec![
            mk(PolicySpec::Msu, 0, 0, Tier::High),
            mk(PolicySpec::UniformProgress, 1, 2, Tier::Normal),
            mk(PolicySpec::Msu, 2, 0, Tier::Low),
            mk(PolicySpec::Ahanp { sigma: 0.5 }, 0, 3, Tier::Low),
        ];
        let rec = engine.run_recorded(&specs);
        for live in 0..specs.len() {
            let plan = ReplayPlan::new(&engine, &specs, &rec, live);
            for cand in [PolicySpec::OdOnly, PolicySpec::Msu, PolicySpec::UniformProgress] {
                let want =
                    engine.run_with_override(&specs, &rec.traces, live, cand);
                assert_eq!(
                    plan.counterfactual(cand),
                    want,
                    "live {live}, cand {}",
                    cand.label()
                );
            }
        }
    }
}
