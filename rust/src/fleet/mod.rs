//! Fleet layer: multi-job, multi-region spot simulation with shared,
//! contended capacity — the cluster-scale generalization of the paper's
//! per-job episode.
//!
//! - [`region`] — a [`region::RegionSet`] of independently traced
//!   regional spot markets plus the migration-cost model;
//! - [`capacity`] — the shared-capacity arbiter (fair-share water-fill
//!   with priority tiers and cascading preemption);
//! - [`engine`] — the [`engine::FleetEngine`] stepping every job
//!   slot-by-slot under its own policy, with the invariant that a
//!   1-job/1-region fleet reproduces `run_episode` bit-for-bit, plus the
//!   record/replay API ([`engine::FleetEngine::run_recorded`] /
//!   [`engine::FleetEngine::run_with_override`]) that makes one-job
//!   counterfactuals cheap;
//! - [`events`] — the event-driven stepper full runs route through:
//!   per-region event queues, dirty-set arbitration (clean slots take
//!   the proven answer instead of re-running the arbiter), and a
//!   region-sharded parallel slot loop — bit-identical to the dense
//!   reference loop at 100k-job scale;
//! - [`replay`] — the delta-replay counterfactual engine: a
//!   [`replay::ReplayPlan`] compacts a recorded run once, then evaluates
//!   each candidate override in time proportional to how much it
//!   *differs* from the recording (clean-slot short-circuit + prefix
//!   forking across candidates), bit-for-bit identical to
//!   `run_with_override`;
//! - [`select`] — fleet-aware policy selection: the EG learner's
//!   counterfactuals evaluated *under contention*, each candidate
//!   swapped into the fleet while the other jobs replay their committed
//!   choices;
//! - [`sweep`] — the `std::thread::scope`-based parallel executor that
//!   fleets, benches, and both selectors' counterfactual evaluations
//!   route through.

pub mod capacity;
pub mod engine;
pub mod events;
pub mod region;
pub mod replay;
pub mod select;
pub mod sweep;

pub use capacity::{arbitrate, SpotGrant, SpotRequest, Tier};
pub use engine::{
    CommittedRun, CommittedTrace, FleetEngine, FleetJobSpec, FleetResult,
    JobOutcome,
};
pub use region::{MigrationMode, MigrationModel, Region, RegionSet};
pub use replay::{ReplayPlan, ReplayStats};
pub use select::{
    run_fleet_selection, run_fleet_selection_observed, FleetContendedEvaluator,
};
pub use sweep::{
    available_threads, run_fleet_sweep, run_parallel, run_parallel_with,
    run_selection_parallel, run_selection_parallel_observed, FleetScenario,
};
