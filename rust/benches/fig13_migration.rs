//! Fig. 13 (ours) — reactive vs predictive migration under a
//! **correlated regional capacity shift**: the provider drains spot
//! capacity out of one region and fills another mid-horizon (the
//! real-world rebalancing pattern SkyNomad documents). The starvation
//! reflex can only move a job *after* its region has collapsed (and an
//! AHAP that quietly substitutes on-demand never even trips it);
//! region-aware planning (`--migration policy`) prices every region's
//! forecast window inside the CHC subproblem and moves *before* the
//! collapse bites.
//!
//! The scripted core asserts the acceptance criterion — predictive
//! migration strictly beats the reflex on fleet utility — and a seeded
//! sweep reports the gap across random fleets on the same shift
//! pattern. `--smoke` runs the scripted core only (the CI rot check).

use spotfine::fleet::{
    FleetEngine, FleetJobSpec, MigrationMode, MigrationModel, Region,
    RegionSet, Tier,
};
use spotfine::market::trace::SpotTrace;
use spotfine::sched::job::Job;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{PolicySpec, PredictorKind};
use spotfine::util::bench::{section, time_once};
use spotfine::util::csvio::CsvWriter;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

/// Three regions with a correlated capacity shift at `shift`: region 0
/// drains (12 → 0), region 1 fills (1 → 12), region 2 stays a shallow
/// constant — total capacity is roughly conserved, it just *moves*.
fn shifting_regions(shift: usize, slots: usize, jitter: u64) -> RegionSet {
    let step = |hi: u32, lo: u32| -> Vec<u32> {
        (0..slots).map(|t| if t < shift { hi } else { lo }).collect()
    };
    // Small deterministic price jitter so sweeps differ across seeds.
    let price = |base: f64| -> Vec<f64> {
        (0..slots)
            .map(|t| base + 0.01 * ((t as u64 ^ jitter) % 5) as f64)
            .collect()
    };
    RegionSet::new(vec![
        Region {
            name: "draining".into(),
            trace: SpotTrace::new(price(0.30), step(12, 0)),
        },
        Region {
            name: "filling".into(),
            trace: SpotTrace::new(price(0.35), step(1, 12)),
        },
        Region {
            name: "shallow".into(),
            trace: SpotTrace::new(price(0.45), vec![4; slots]),
        },
    ])
    .with_migration(MigrationModel::new(1.0, 0.5))
}

/// A fleet of AHAP jobs homed in the draining region (the ones whose
/// migration policy matters) plus spot-greedy background elsewhere.
fn fleet(seed: u64) -> Vec<FleetJobSpec> {
    let omegas = [5usize, 4, 5, 3, 4, 5];
    let mut specs: Vec<FleetJobSpec> = omegas
        .iter()
        .enumerate()
        .map(|(k, &omega)| {
            let job = Job {
                workload: 100.0 + 5.0 * (k % 3) as f64,
                deadline: 18,
                n_min: 1,
                n_max: 12,
                value: 180.0,
                gamma: 1.5,
            };
            FleetJobSpec::new(
                job,
                PolicySpec::Ahap { omega, v: 1, sigma: 0.7 },
                PredictorKind::Oracle,
            )
            .with_seed(seed ^ (k as u64 + 1))
            .with_tier(Tier::cycle(k))
        })
        .collect();
    specs.push(
        FleetJobSpec::new(
            Job { workload: 60.0, deadline: 18, n_min: 1, n_max: 8, value: 90.0, gamma: 1.5 },
            PolicySpec::Msu,
            PredictorKind::Oracle,
        )
        .in_region(1)
        .with_tier(Tier::Low),
    );
    specs
}

fn run_mode(mode: MigrationMode, seed: u64) -> (f64, u32) {
    let engine =
        FleetEngine::new(Models::paper_default(), shifting_regions(8, 24, seed))
            .with_migration_patience(2)
            .with_migration_mode(mode);
    let r = engine.run(&fleet(seed));
    (r.total_utility, r.total_migrations)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("=== Fig. 13: reactive vs predictive migration ===");
    println!(
        "correlated capacity shift at slot 8 (region 0 drains, region 1 fills){}\n",
        if smoke { "  [smoke]" } else { "" }
    );

    let mut csv = CsvWriter::create(
        "results/fig13_migration.csv",
        &["seed", "reactive_utility", "predictive_utility", "reactive_moves", "predictive_moves"],
    )
    .expect("csv");

    section("scripted shift (acceptance gate)");
    let ((reactive_u, reactive_m), r_secs) =
        time_once(|| run_mode(MigrationMode::Starvation, 0));
    let ((predictive_u, predictive_m), p_secs) =
        time_once(|| run_mode(MigrationMode::Policy, 0));
    let mut t = Table::new(&["migration", "fleet utility", "moves", "secs"]);
    t.row(&[
        "starvation reflex".into(),
        f(reactive_u, 2),
        format!("{reactive_m}"),
        format!("{r_secs:.3}"),
    ]);
    t.row(&[
        "policy (region-aware)".into(),
        f(predictive_u, 2),
        format!("{predictive_m}"),
        format!("{p_secs:.3}"),
    ]);
    t.print();
    csv.row(&[
        "0".into(),
        format!("{reactive_u:.4}"),
        format!("{predictive_u:.4}"),
        format!("{reactive_m}"),
        format!("{predictive_m}"),
    ]);
    assert!(
        predictive_u > reactive_u,
        "ACCEPTANCE MISSED: predictive migration {predictive_u:.2} must beat \
         the starvation reflex {reactive_u:.2} under the capacity shift"
    );
    assert!(
        predictive_m >= 1,
        "region-aware planning never migrated (moves {predictive_m})"
    );
    println!(
        "\npredictive advantage: {:+.2} fleet utility ({} vs {} moves)",
        predictive_u - reactive_u,
        predictive_m,
        reactive_m
    );

    if !smoke {
        section("seeded sweep (same shift, jittered prices/jobs)");
        let mut gaps = Vec::new();
        for seed in 1..=12u64 {
            let (ru, rm) = run_mode(MigrationMode::Starvation, seed);
            let (pu, pm) = run_mode(MigrationMode::Policy, seed);
            gaps.push(pu - ru);
            csv.row(&[
                format!("{seed}"),
                format!("{ru:.4}"),
                format!("{pu:.4}"),
                format!("{rm}"),
                format!("{pm}"),
            ]);
        }
        println!(
            "mean predictive advantage over 12 seeds: {:+.2} (min {:+.2}, max {:+.2})",
            stats::mean(&gaps),
            gaps.iter().cloned().fold(f64::INFINITY, f64::min),
            gaps.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        );
    }

    let path = csv.finish().expect("write csv");
    println!("wrote {}", path.display());
}
