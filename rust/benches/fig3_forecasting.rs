//! Fig. 3 — forecasting spot availability and price with ARIMA
//! (30-minute windows): "predictions closely match the actual
//! fluctuations". Regenerated as 1..5-step-ahead accuracy of our
//! ARIMA(3,0,1)+seasonal against persistence and seasonal-naive
//! baselines, averaged over 5 market seeds.

use spotfine::forecast::arima::ArimaPredictor;
use spotfine::forecast::baseline::{PersistencePredictor, SeasonalNaivePredictor};
use spotfine::forecast::predictor::Predictor;
use spotfine::market::generator::TraceGenerator;
use spotfine::market::trace::SpotTrace;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

fn eval(
    make: &dyn Fn() -> Box<dyn Predictor>,
    trace: &SpotTrace,
    horizon: usize,
) -> (f64, f64) {
    let split = trace.len() * 7 / 10;
    let mut pred = make();
    for t in 0..split {
        pred.observe(t, trace.price_at(t), trace.avail_at(t));
    }
    let mut pt = Vec::new();
    let mut ph = Vec::new();
    let mut at = Vec::new();
    let mut ah = Vec::new();
    for t in split..trace.len() - horizon {
        let fc = pred.predict(horizon);
        ph.push(fc.price[horizon - 1]);
        ah.push(fc.avail[horizon - 1]);
        pt.push(trace.price_at(t + horizon - 1));
        at.push(trace.avail_at(t + horizon - 1) as f64);
        pred.observe(t, trace.price_at(t), trace.avail_at(t));
    }
    (stats::rmse(&pt, &ph), stats::rmse(&at, &ah))
}

fn main() {
    println!("=== Fig. 3: ARIMA forecast accuracy (RMSE, 5 seeds) ===");
    let gen = TraceGenerator::calibrated();
    let seeds: Vec<u64> = (0..5).collect();

    let forecasters: Vec<(&str, Box<dyn Fn() -> Box<dyn Predictor>>)> = vec![
        (
            "ARIMA(3,0,1)+s48",
            Box::new(|| Box::new(ArimaPredictor::with_defaults()) as Box<dyn Predictor>),
        ),
        (
            "persistence",
            Box::new(|| Box::new(PersistencePredictor::new()) as Box<dyn Predictor>),
        ),
        (
            "seasonal-naive",
            Box::new(|| Box::new(SeasonalNaivePredictor::new(48)) as Box<dyn Predictor>),
        ),
    ];

    let mut table = Table::new(&[
        "forecaster", "h", "price RMSE", "avail RMSE",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig3_forecast.csv",
        &["forecaster", "horizon", "price_rmse", "avail_rmse"],
    )
    .expect("csv");

    let mut arima_avail = Vec::new();
    let mut persist_avail = Vec::new();
    for (name, make) in &forecasters {
        for h in [1usize, 3, 5, 12, 24] {
            let mut pr = Vec::new();
            let mut ar = Vec::new();
            for &seed in &seeds {
                let trace = gen.generate(seed);
                let (p, a) = eval(make.as_ref(), &trace, h);
                pr.push(p);
                ar.push(a);
            }
            table.row(&[
                name.to_string(),
                h.to_string(),
                f(stats::mean(&pr), 4),
                f(stats::mean(&ar), 3),
            ]);
            csv.row(&[
                name.to_string(),
                h.to_string(),
                format!("{:.6}", stats::mean(&pr)),
                format!("{:.6}", stats::mean(&ar)),
            ]);
            if h >= 12 {
                if *name == "ARIMA(3,0,1)+s48" {
                    arima_avail.push(stats::mean(&ar));
                } else if *name == "persistence" {
                    persist_avail.push(stats::mean(&ar));
                }
            }
        }
    }
    table.print();
    csv.finish().expect("csv");

    // Shape: at multi-hour horizons, the seasonal ARIMA must clearly
    // beat persistence on availability (it knows the diurnal cycle the
    // paper's Fig. 3 shows; persistence cannot).
    for (a, p) in arima_avail.iter().zip(&persist_avail) {
        assert!(
            a < &(p * 0.95),
            "shape violated: ARIMA avail RMSE {a} not clearly below persistence {p}"
        );
    }

    // One-seed overlay series for plotting (forecast vs actual), as in
    // the paper's figure.
    let trace = gen.generate(3);
    let split = trace.len() * 7 / 10;
    let mut pred = ArimaPredictor::with_defaults();
    for t in 0..split {
        pred.observe(t, trace.price_at(t), trace.avail_at(t));
    }
    let mut csv2 = CsvWriter::create(
        "results/fig3_overlay.csv",
        &["slot", "price_true", "price_pred", "avail_true", "avail_pred"],
    )
    .expect("csv");
    for t in split..trace.len() - 1 {
        let fc = pred.predict(1);
        csv2.row_f64(&[
            t as f64,
            trace.price_at(t),
            fc.price[0],
            trace.avail_at(t) as f64,
            fc.avail[0],
        ]);
        pred.observe(t, trace.price_at(t), trace.avail_at(t));
    }
    csv2.finish().expect("csv");
    println!("\nshape check: ARIMA ≤ persistence on both series (predictability");
    println!("the paper exploits); overlay series → results/fig3_overlay.csv");
}
