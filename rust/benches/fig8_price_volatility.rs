//! Fig. 8 — impact of spot-price fluctuation. Paper shape: AHAP/AHANP
//! remain among the top performers across all volatility settings;
//! higher volatility widens the gap between price-aware policies (AHAP's
//! σ-threshold, AHANP's p̂ indicator) and price-blind ones (UP/MSU buy
//! spot at any price).

#[path = "sweep_common.rs"]
mod sweep_common;

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::GeneratorConfig;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};
use sweep_common::evaluate_point;

fn main() {
    println!("=== Fig. 8: utility vs price volatility ===");
    let vols = [0.3f64, 0.6, 1.0, 1.5, 2.0];
    let n_jobs = 120;
    let noise = NoiseSpec::fixed_mag_uniform(0.1);
    let jobs = JobGenerator::default();
    let models = Models::paper_default();

    let mut table = Table::new(&[
        "volatility", "OD-Only", "MSU", "UP", "AHANP", "AHAP",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig8_volatility.csv",
        &["volatility", "group", "utility", "misses"],
    )
    .expect("csv");
    let mut gaps = Vec::new();
    for &vol in &vols {
        let gen_cfg = GeneratorConfig { volatility: vol, ..GeneratorConfig::default() };
        let scores = evaluate_point(&gen_cfg, &jobs, &models, noise, n_jobs, 42);
        let get = |n: &str| scores.iter().find(|s| s.name == n).unwrap();
        table.row(&[
            f(vol, 1),
            f(get("OD-Only").utility, 1),
            f(get("MSU").utility, 1),
            f(get("UP").utility, 1),
            f(get("AHANP").utility, 1),
            f(get("AHAP").utility, 1),
        ]);
        for s in &scores {
            csv.row(&[
                format!("{vol:.1}"),
                s.name.to_string(),
                format!("{:.4}", s.utility),
                s.misses.to_string(),
            ]);
        }
        gaps.push(get("AHAP").utility - get("UP").utility);
    }
    table.print();
    csv.finish().expect("csv");

    // Shape: AHAP's edge over the price-blind UP does not shrink as
    // volatility grows (more exploitable price structure).
    println!("\nAHAP − UP gap by volatility: {:?}",
        gaps.iter().map(|g| (g * 10.0).round() / 10.0).collect::<Vec<_>>());
    assert!(
        *gaps.last().unwrap() >= *gaps.first().unwrap() - 1.0,
        "shape violated: volatility should not erase AHAP's price-aware edge"
    );
    println!("shape OK; wrote results/fig8_volatility.csv");
}
