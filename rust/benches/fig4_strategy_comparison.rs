//! Fig. 4 — workload/cost comparison of five allocation strategies on
//! the didactic example: L=20, d=5 slots, p^o=1, spot prices
//! (.5, .7, .3, .5, .3), no reconfiguration cost.
//!
//! Paper's qualitative claims reproduced here:
//!   - OD-Only: completes, highest cost;
//!   - Spot-First: cheapest-per-unit but deadline-risky;
//!   - Progress-Tracking: completes but under-exploits cheap spot;
//!   - Perfect-Predictor: completes at the minimum cost (= offline OPT);
//!   - Imperfect-Predictor: between the two.

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::trace::SpotTrace;
use spotfine::sched::job::Job;
use spotfine::sched::offline::solve_offline;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{PolicyEnv, PolicySpec, PredictorKind};
use spotfine::sched::simulate::run_episode;
use spotfine::sched::throughput::{ReconfigModel, ThroughputModel};
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};

fn main() {
    println!("=== Fig. 4: strategy comparison (L=20, d=5, p^o=1) ===");
    let models = Models {
        throughput: ThroughputModel::unit(),
        reconfig: ReconfigModel::free(),
        on_demand_price: 1.0,
    };
    let job = Job { workload: 20.0, deadline: 5, n_min: 1, n_max: 8, value: 30.0, gamma: 1.6 };
    let trace = SpotTrace::new(vec![0.5, 0.7, 0.3, 0.5, 0.3], vec![6, 2, 6, 6, 0]);

    let strategies: Vec<(&str, PolicySpec, PredictorKind)> = vec![
        ("On-Demand Only", PolicySpec::OdOnly, PredictorKind::Oracle),
        ("Spot-First", PolicySpec::Msu, PredictorKind::Oracle),
        ("Progress-Tracking", PolicySpec::UniformProgress, PredictorKind::Oracle),
        (
            "Perfect-Predictor",
            PolicySpec::Ahap { omega: 4, v: 1, sigma: 0.6 },
            PredictorKind::Oracle,
        ),
        (
            "Imperfect-Predictor",
            PolicySpec::Ahap { omega: 4, v: 1, sigma: 0.6 },
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.6)),
        ),
    ];

    let mut table =
        Table::new(&["strategy", "workload", "cost", "utility", "decision trace"]);
    let mut csv = CsvWriter::create(
        "results/fig4_strategies.csv",
        &["strategy", "workload", "cost", "utility"],
    )
    .expect("csv");
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for (name, spec, pk) in strategies {
        let env = PolicyEnv::new(pk, trace.clone(), 3);
        let mut p = spec.build(&env);
        let r = run_episode(&job, &trace, &models, p.as_mut());
        let dec = r
            .decisions
            .iter()
            .map(|a| format!("{}o+{}s", a.on_demand, a.spot))
            .collect::<Vec<_>>()
            .join(" ");
        table.row(&[
            name.to_string(),
            f(r.progress_at_deadline, 1),
            f(r.cost, 2),
            f(r.utility, 2),
            dec,
        ]);
        csv.row(&[
            name.to_string(),
            format!("{:.1}", r.progress_at_deadline),
            format!("{:.2}", r.cost),
            format!("{:.2}", r.utility),
        ]);
        rows.push((name.to_string(), r.cost, r.utility));
    }
    let opt = solve_offline(&job, &trace, &models, 0.05);
    table.row(&[
        "Offline OPT".into(),
        "20.0".into(),
        f(job.value - opt.utility, 2),
        f(opt.utility, 2),
        opt.alloc
            .iter()
            .map(|a| format!("{}o+{}s", a.on_demand, a.spot))
            .collect::<Vec<_>>()
            .join(" "),
    ]);
    table.print();
    csv.finish().expect("csv");

    // Shape assertions (the paper's ordering).
    let cost = |n: &str| rows.iter().find(|r| r.0 == n).unwrap().1;
    assert!(cost("On-Demand Only") > cost("Perfect-Predictor"),
        "OD must cost more than perfect prediction");
    assert!(
        (cost("Perfect-Predictor") - (job.value - opt.utility)).abs() < 1e-6,
        "perfect predictor must hit the offline optimum on this instance"
    );
    assert!(cost("Imperfect-Predictor") >= cost("Perfect-Predictor"),
        "imperfect prediction can only cost more");
    println!("\nshape OK: OD > Imperfect ≥ Perfect = OPT; wrote results/fig4_strategies.csv");
}
