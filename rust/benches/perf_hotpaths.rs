//! Performance microbenchmarks of every hot path (§Perf deliverable):
//!
//!   L3 targets (DESIGN.md §Perf): AHAP decision ≤ 1 ms, full 112-policy
//!   counterfactual job ≤ 150 ms, EG update ≤ 10 µs.
//!
//! Plus the PJRT step time when artifacts are present (L2/L1 path).

use spotfine::forecast::noise::NoiseSpec;
use spotfine::forecast::predictor::{OraclePredictor, Predictor};
use spotfine::market::generator::TraceGenerator;
use spotfine::market::market::MarketObs;
use spotfine::sched::ahap::Ahap;
use spotfine::sched::horizon::{solve_dp, solve_greedy, HorizonProblem, TerminalKind};
use spotfine::sched::job::{Job, JobGenerator};
use spotfine::sched::offline::solve_offline;
use spotfine::sched::policy::{Models, Policy, SlotContext};
use spotfine::sched::pool::{paper_pool, PolicyEnv, PredictorKind};
use spotfine::sched::selector::EgSelector;
use spotfine::sched::simulate::run_episode;
use spotfine::util::bench::{bench, section};
use spotfine::util::rng::Rng;

fn main() {
    let models = Models::paper_default();
    let job = Job::paper_reference();
    let trace = TraceGenerator::calibrated().generate(3).slice_from(40);

    section("L3: Eq. 10 window solvers");
    let prices: Vec<f64> = (0..6).map(|i| trace.price_at(i)).collect();
    let avail: Vec<u32> = (0..6).map(|i| trace.avail_at(i)).collect();
    let prob = HorizonProblem {
        job: &job,
        models: &models,
        start_slot: 0,
        z0: 10.0,
        prices: &prices,
        avail: &avail,
        n_prev: 4,
        terminal_kind: TerminalKind::Exact,
    };
    let r = bench("greedy solver (ω=5 window)", 100, 2000, || {
        solve_greedy(&prob).utility
    });
    println!("{}", r.line());
    let greedy_us = r.mean_us();
    let r = bench("exact DP solver (ω=5, grid 0.25)", 10, 100, || {
        solve_dp(&prob, 0.25).utility
    });
    println!("{}", r.line());
    let r = bench("offline OPT (d=10, grid 0.1)", 5, 50, || {
        solve_offline(&job, &trace, &models, 0.1).utility
    });
    println!("{}", r.line());

    section("L3: AHAP decision (observe + forecast + solve + commit)");
    let mut ahap = Ahap::new(5, 2, 0.7, Box::new(OraclePredictor::new(trace.clone())));
    let obs = MarketObs {
        t: 2,
        spot_price: trace.price_at(2),
        avail: trace.avail_at(2),
        on_demand_price: 1.0,
    };
    let ctx = SlotContext {
        t: 2,
        obs,
        progress: 8.0,
        prev_total: 6,
        prev_avail: 5,
        job: &job,
        models: &models,
    };
    let r = bench("ahap.decide (behind schedule)", 100, 2000, || {
        ahap.reset();
        ahap.decide(&ctx)
    });
    println!("{}", r.line());
    assert!(
        r.mean_us() < 1000.0,
        "PERF TARGET MISSED: AHAP decision {} µs > 1 ms",
        r.mean_us()
    );

    section("L3: full episode + counterfactual sweep");
    let env = PolicyEnv {
        predictor: PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
        trace: trace.clone(),
        seed: 3,
    };
    let spec = spotfine::sched::pool::PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 };
    let r = bench("one AHAP episode (d=10)", 50, 500, || {
        let mut p = spec.build(&env);
        run_episode(&job, &trace, &models, p.as_mut()).utility
    });
    println!("{}", r.line());

    let pool = paper_pool();
    let jobs = JobGenerator::default();
    let mut rng = Rng::new(9);
    let j = jobs.sample(&mut rng);
    let r = bench("112-policy counterfactual job", 2, 20, || {
        let mut total = 0.0;
        for s in &pool {
            let mut p = s.build(&env);
            total += run_episode(&j, &trace, &models, p.as_mut()).utility;
        }
        total
    });
    println!("{}", r.line());
    assert!(
        r.mean_ms() < 150.0,
        "PERF TARGET MISSED: counterfactual sweep {} ms > 150 ms",
        r.mean_ms()
    );

    section("L3: EG selector update (M=112)");
    let mut sel = EgSelector::new(112, 1000);
    let us: Vec<f64> = (0..112).map(|i| (i as f64 / 112.0)).collect();
    let r = bench("eg.update", 1000, 20000, || sel.update(&us));
    println!("{}", r.line());
    assert!(
        r.mean_us() < 10.0,
        "PERF TARGET MISSED: EG update {} µs > 10 µs",
        r.mean_us()
    );

    section("forecasting");
    let mut arima = spotfine::forecast::arima::ArimaPredictor::with_defaults();
    arima.seed_history(&trace.price[..200.min(trace.len())], &trace.avail_f64()[..200.min(trace.len())]);
    let r = bench("ARIMA refit + 5-step predict", 3, 30, || arima.predict(5));
    println!("{}", r.line());

    section("L2/L1: PJRT train step (needs artifacts)");
    let dir = std::path::PathBuf::from("artifacts");
    if spotfine::runtime::artifact::ArtifactBundle::present(&dir) {
        let client = spotfine::runtime::client::RuntimeClient::cpu().unwrap();
        let bundle = spotfine::runtime::artifact::ArtifactBundle::load(&dir).unwrap();
        let exec = spotfine::runtime::executable::TrainStepExec::compile(&client, bundle).unwrap();
        let mut trainer = spotfine::train::trainer::Trainer::new(
            exec,
            spotfine::train::trainer::TrainerConfig::default(),
        )
        .unwrap();
        let r = bench("grad+apply step (1 shard)", 1, 5, || {
            trainer.step_parallel(1).unwrap().loss
        });
        println!("{}", r.line());
        let r = bench("grad+apply step (4 shards)", 1, 5, || {
            trainer.step_parallel(4).unwrap().loss
        });
        println!("{}", r.line());
    } else {
        println!("SKIP: artifacts not built");
    }

    println!(
        "\nsummary: greedy solve {:.1} µs/decision — the planner runs ~10⁶× \
         faster than the 30-min slot it schedules.",
        greedy_us
    );
}
