//! Performance microbenchmarks of every hot path (§Perf deliverable):
//!
//!   L3 targets (DESIGN.md §Perf): AHAP decision ≤ 1 ms, full 112-policy
//!   counterfactual job ≤ 150 ms, EG update ≤ 10 µs.
//!
//!   ARIMA targets: incremental refit + 5-step predict ≥ 10× the batch
//!   baseline; an honest-ARIMA 112-policy forecast sweep served by the
//!   shared per-slot cache ≥ 10× per-policy batch predictors.
//!
//!   Fleet-selection target: a 112-candidate contended selection round
//!   through the delta-replay engine ≥ 5× the full `run_with_override`
//!   fleet re-simulation baseline (bit-identical results, asserted).
//!
//! Every section is also recorded to `BENCH_hotpaths.json` (mean/p50/p95
//! µs per bench plus named baseline-vs-current speedups) so the perf
//! trajectory is tracked across PRs. Pass `--baseline <path>` (CI points
//! it at the committed repo-root `BENCH_hotpaths.json`) to diff this run
//! against the recorded trajectory: per-bench ratios are printed, and
//! the run fails if any baseline bench is missing from this run (perf
//! coverage must never silently shrink).
//!
//! Plus the PJRT step time when artifacts are present (L2/L1 path).

use spotfine::fleet::{FleetContendedEvaluator, MigrationMode};
use spotfine::forecast::arima::{ArimaConfig, ArimaPredictor};
use spotfine::forecast::cache::{MarketHistory, SharedForecaster};
use spotfine::forecast::noise::NoiseSpec;
use spotfine::forecast::predictor::{OraclePredictor, Predictor};
use spotfine::market::generator::TraceGenerator;
use spotfine::market::market::MarketObs;
use spotfine::obs::Recorder;
use spotfine::sched::ahap::Ahap;
use spotfine::sched::horizon::{solve_dp, solve_greedy, HorizonProblem, TerminalKind};
use spotfine::sched::job::{Job, JobGenerator};
use spotfine::sched::offline::solve_offline;
use spotfine::sched::policy::{Models, Policy, SlotContext};
use spotfine::sched::pool::{paper_pool, PolicyEnv, PolicySpec, PredictorKind};
use spotfine::sched::selector::EgSelector;
use spotfine::sched::simulate::run_episode;
use spotfine::sched::warm::WarmState;
use spotfine::util::bench::{bench, section, JsonReport};
use spotfine::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let baseline_path = argv
        .iter()
        .position(|a| a == "--baseline")
        .and_then(|i| argv.get(i + 1).cloned());
    let mut report = JsonReport::new("perf_hotpaths");
    let models = Models::paper_default();
    let job = Job::paper_reference();
    let trace = TraceGenerator::calibrated().generate(3).slice_from(40);

    section("L3: Eq. 10 window solvers");
    let prices: Vec<f64> = (0..6).map(|i| trace.price_at(i)).collect();
    let avail: Vec<u32> = (0..6).map(|i| trace.avail_at(i)).collect();
    let prob = HorizonProblem {
        job: &job,
        models: &models,
        start_slot: 0,
        z0: 10.0,
        prices: &prices,
        avail: &avail,
        n_prev: 4,
        terminal_kind: TerminalKind::Exact,
        migration: None,
    };
    let r = bench("greedy solver (ω=5 window)", 100, 2000, || {
        solve_greedy(&prob).utility
    });
    println!("{}", r.line());
    report.result("solvers", &r);
    let greedy_us = r.mean_us();
    let r = bench("exact DP solver (ω=5, grid 0.25)", 10, 100, || {
        solve_dp(&prob, 0.25).utility
    });
    println!("{}", r.line());
    report.result("solvers", &r);
    let r = bench("offline OPT (d=10, grid 0.1)", 5, 50, || {
        solve_offline(&job, &trace, &models, 0.1).utility
    });
    println!("{}", r.line());
    report.result("solvers", &r);

    section("solvers: warm-started sliding windows (ω=5)");
    // The warm solvers' home turf: AHAP re-solving overlapping windows
    // slot after slot. A wide job (n_max 48 → ~240 menu units per
    // window) makes the cold per-window menu rebuild + sort visible;
    // the incremental menu moves ≤2 runs per slide and the scan
    // early-exits at workload saturation. Bit-identity is asserted
    // before anything is timed (the full property suite lives in
    // tests/warm_solver_properties.rs).
    let wide_job = Job {
        workload: 80.0,
        deadline: 40,
        n_min: 1,
        n_max: 48,
        value: 160.0,
        gamma: 1.5,
    };
    let slides = 30usize;
    let strip_p: Vec<f64> =
        (0..slides + 5).map(|i| trace.price_at(i % trace.len())).collect();
    let strip_a: Vec<u32> = (0..slides + 5)
        .map(|i| (trace.avail_at(i % trace.len()) * 4 + 3).min(52))
        .collect();
    let win_prob = |t: usize, z0: f64| HorizonProblem {
        job: &wide_job,
        models: &models,
        start_slot: t,
        z0,
        prices: &strip_p[t..t + 5],
        avail: &strip_a[t..t + 5],
        n_prev: 8,
        terminal_kind: TerminalKind::LinearCost,
        migration: None,
    };
    {
        let mut ws = WarmState::default();
        let mut z0 = 20.0;
        for t in 0..slides {
            let p = win_prob(t, z0);
            ws.begin_decision();
            let w = ws.solve_greedy(&p, true);
            let c = solve_greedy(&p);
            assert_eq!(w.alloc, c.alloc, "warm greedy diverged at slide {t}");
            assert_eq!(w.utility.to_bits(), c.utility.to_bits());
            z0 += 1.5;
        }
    }
    let r_cold_seq =
        bench("greedy sliding sequence, cold (30 slides, n_max 48)", 10, 200, || {
            let mut acc = 0.0;
            let mut z0 = 20.0;
            for t in 0..slides {
                acc += solve_greedy(&win_prob(t, z0)).utility;
                z0 += 1.5;
            }
            acc
        });
    println!("{}", r_cold_seq.line());
    report.result("solvers", &r_cold_seq);
    let mut warm_greedy = WarmState::default();
    let r_warm_seq =
        bench("greedy sliding sequence, warm (30 slides, n_max 48)", 10, 200, || {
            let mut acc = 0.0;
            let mut z0 = 20.0;
            for t in 0..slides {
                let p = win_prob(t, z0);
                warm_greedy.begin_decision();
                acc += warm_greedy.solve_greedy(&p, true).utility;
                z0 += 1.5;
            }
            acc
        });
    println!("{}", r_warm_seq.line());
    report.result("solvers", &r_warm_seq);
    let warm_greedy_speedup = report.speedup(
        "warm greedy sliding sequence (ω=5)",
        r_cold_seq.mean_us(),
        r_warm_seq.mean_us(),
    );
    println!("speedup: {warm_greedy_speedup:.1}x (incremental menu over cold rebuild)");
    assert!(
        warm_greedy_speedup >= 5.0,
        "PERF TARGET MISSED: warm greedy only {warm_greedy_speedup:.1}x over cold on the sliding sequence"
    );

    // Warm DP under the harsh-μ regime the automatic dispatch routes to
    // it, seeded each slide with the previous committed plan.
    let dp_models = Models {
        reconfig: spotfine::sched::throughput::ReconfigModel::new(0.5, 0.7),
        ..models
    };
    let dp_slides = 5usize;
    let dp_prob = |t: usize, z0: f64| HorizonProblem {
        job: &job,
        models: &dp_models,
        start_slot: t,
        z0,
        prices: &strip_p[t..t + 5],
        avail: &strip_a[t..t + 5],
        n_prev: 4,
        terminal_kind: TerminalKind::LinearCost,
        migration: None,
    };
    {
        let mut ws = WarmState::default();
        let mut z0 = 0.0;
        for t in 0..dp_slides {
            let p = dp_prob(t, z0);
            let w = ws.solve_dp(&p, 0.1, true);
            let c = solve_dp(&p, 0.1);
            assert_eq!(w.alloc, c.alloc, "warm DP diverged at slide {t}");
            assert_eq!(w.utility.to_bits(), c.utility.to_bits());
            ws.note_home_plan(t, &w.alloc);
            z0 += 4.0;
        }
    }
    let r_cold_dp =
        bench("exact DP sliding sequence, cold (5 slides, grid 0.1)", 3, 30, || {
            let mut acc = 0.0;
            let mut z0 = 0.0;
            for t in 0..dp_slides {
                acc += solve_dp(&dp_prob(t, z0), 0.1).utility;
                z0 += 4.0;
            }
            acc
        });
    println!("{}", r_cold_dp.line());
    report.result("solvers", &r_cold_dp);
    let mut warm_dp = WarmState::default();
    let r_warm_dp = bench(
        "exact DP sliding sequence, warm-seeded (5 slides, grid 0.1)",
        3,
        30,
        || {
            let mut acc = 0.0;
            let mut z0 = 0.0;
            for t in 0..dp_slides {
                let p = dp_prob(t, z0);
                let s = warm_dp.solve_dp(&p, 0.1, true);
                warm_dp.note_home_plan(t, &s.alloc);
                acc += s.utility;
                z0 += 4.0;
            }
            acc
        },
    );
    println!("{}", r_warm_dp.line());
    report.result("solvers", &r_warm_dp);
    let warm_dp_speedup = report.speedup(
        "warm DP sliding sequence (grid 0.1)",
        r_cold_dp.mean_us(),
        r_warm_dp.mean_us(),
    );
    println!("speedup: {warm_dp_speedup:.1}x (reachable-state memo + incumbent bound)");
    assert!(
        warm_dp_speedup >= 1.2,
        "PERF TARGET MISSED: warm DP only {warm_dp_speedup:.1}x over cold on the sliding sequence"
    );

    // One deterministic portfolio round: both racers inline, DP adopted
    // iff strictly better. The budget here is a loose sanity ceiling —
    // the round must stay in the same order as greedy + DP themselves.
    let mut portfolio = WarmState::default();
    let r_port =
        bench("portfolio round, deterministic (greedy + DP 0.25)", 10, 200, || {
            portfolio.begin_decision();
            portfolio.race(&prob, 0.25, None, true).utility
        });
    println!("{}", r_port.line());
    report.result("solvers", &r_port);
    assert!(
        r_port.mean_us() < 5_000.0,
        "PERF TARGET MISSED: deterministic portfolio round {} µs > 5 ms",
        r_port.mean_us()
    );

    section("L3: AHAP decision (observe + forecast + solve + commit)");
    let mut ahap = Ahap::new(5, 2, 0.7, Box::new(OraclePredictor::new(trace.clone())));
    let obs = MarketObs {
        t: 2,
        spot_price: trace.price_at(2),
        avail: trace.avail_at(2),
        on_demand_price: 1.0,
    };
    let ctx = SlotContext {
        t: 2,
        obs,
        progress: 8.0,
        prev_total: 6,
        prev_avail: 5,
        job: &job,
        models: &models,
    };
    let r = bench("ahap.decide (behind schedule)", 100, 2000, || {
        ahap.reset();
        ahap.decide(&ctx)
    });
    println!("{}", r.line());
    report.result("ahap", &r);
    assert!(
        r.mean_us() < 1000.0,
        "PERF TARGET MISSED: AHAP decision {} µs > 1 ms",
        r.mean_us()
    );

    section("L3: full episode + counterfactual sweep");
    let env = PolicyEnv::new(
        PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
        trace.clone(),
        3,
    );
    let spec = PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 };
    let r = bench("one AHAP episode (d=10)", 50, 500, || {
        let mut p = spec.build(&env);
        run_episode(&job, &trace, &models, p.as_mut()).utility
    });
    println!("{}", r.line());
    report.result("episodes", &r);

    let pool = paper_pool();
    let jobs = JobGenerator::default();
    let mut rng = Rng::new(9);
    let j = jobs.sample(&mut rng);
    let r = bench("112-policy counterfactual job", 2, 20, || {
        let mut total = 0.0;
        for s in &pool {
            let mut p = s.build(&env);
            total += run_episode(&j, &trace, &models, p.as_mut()).utility;
        }
        total
    });
    println!("{}", r.line());
    report.result("episodes", &r);
    assert!(
        r.mean_ms() < 150.0,
        "PERF TARGET MISSED: counterfactual sweep {} ms > 150 ms",
        r.mean_ms()
    );

    section("L3: EG selector update (M=112)");
    let mut sel = EgSelector::new(112, 1000);
    let us: Vec<f64> = (0..112).map(|i| (i as f64 / 112.0)).collect();
    let r = bench("eg.update", 1000, 20000, || sel.update(&us));
    println!("{}", r.line());
    report.result("selector", &r);
    assert!(
        r.mean_us() < 10.0,
        "PERF TARGET MISSED: EG update {} µs > 10 µs",
        r.mean_us()
    );

    // --- Forecasting: the pool-sweep hot path -----------------------
    // An honest-ARIMA predictor over a market with 300 slots of seeded
    // history (the realistic setting: the forecaster knows the market's
    // past days). The pre-change code refit two full-history ridge
    // regressions per slot *per policy*; the incremental fitter makes a
    // refit O(k²), and the shared cache collapses the pool's ~105
    // per-slot fits into one.
    let full = TraceGenerator::calibrated().generate(12);
    let warm = 300usize.min(full.len());
    let hist = MarketHistory::from_trace(&full, warm);
    let ep_trace = full.slice_from(warm);
    let batch_cfg = ArimaConfig { incremental: false, ..ArimaConfig::default() };
    let inc_cfg = ArimaConfig::default();
    let seeded = |cfg: ArimaConfig| {
        let mut p = ArimaPredictor::configured(cfg);
        p.seed_history(&hist.price, &hist.avail);
        p
    };

    section("forecasting: ARIMA refit + 5-step predict");
    let mut batch_pred = seeded(batch_cfg);
    let mut t = warm;
    let r_batch = bench("ARIMA refit + 5-step predict (batch baseline)", 3, 40, || {
        batch_pred.observe(t, full.price_at(t % full.len()), full.avail_at(t % full.len()));
        t += 1;
        batch_pred.predict(5)
    });
    println!("{}", r_batch.line());
    report.result("forecasting", &r_batch);
    let mut inc_pred = seeded(inc_cfg);
    let mut t = warm;
    let r_inc = bench("ARIMA refit + 5-step predict (incremental)", 20, 1000, || {
        inc_pred.observe(t, full.price_at(t % full.len()), full.avail_at(t % full.len()));
        t += 1;
        inc_pred.predict(5)
    });
    println!("{}", r_inc.line());
    report.result("forecasting", &r_inc);
    let refit_speedup =
        report.speedup("ARIMA refit + 5-step predict", r_batch.mean_us(), r_inc.mean_us());
    println!("speedup: {refit_speedup:.1}x (incremental over batch)");
    assert!(
        refit_speedup >= 10.0,
        "PERF TARGET MISSED: incremental ARIMA refit only {refit_speedup:.1}x over batch"
    );
    assert!(
        r_inc.mean_us() < 500.0,
        "PERF TARGET MISSED: incremental refit+predict {} µs > 500 µs",
        r_inc.mean_us()
    );

    section("forecasting: ARIMA forecast layer, 112-policy sweep");
    // The pool's forecast work for one 10-slot counterfactual job: every
    // AHAP policy observes each slot and forecasts its ω-step window.
    let omegas: Vec<usize> =
        pool.iter().map(|s| s.omega()).filter(|&w| w > 0).collect();
    let slots = 10usize;
    let r_layer_batch = bench("forecast layer (per-policy batch)", 1, 3, || {
        let mut acc = 0.0;
        for &w in &omegas {
            let mut p = seeded(batch_cfg);
            for t in 0..slots {
                p.observe(t, ep_trace.price_at(t), ep_trace.avail_at(t));
                acc += p.predict(w).price[0];
            }
        }
        acc
    });
    println!("{}", r_layer_batch.line());
    report.result("forecasting", &r_layer_batch);
    let r_layer_inc = bench("forecast layer (per-policy incremental)", 2, 10, || {
        let mut acc = 0.0;
        for &w in &omegas {
            let mut p = seeded(inc_cfg);
            for t in 0..slots {
                p.observe(t, ep_trace.price_at(t), ep_trace.avail_at(t));
                acc += p.predict(w).price[0];
            }
        }
        acc
    });
    println!("{}", r_layer_inc.line());
    report.result("forecasting", &r_layer_inc);
    let r_layer_cached = bench("forecast layer (shared cache, cold)", 2, 20, || {
        // Cache built inside the closure: the cost includes the one
        // shared seed + per-slot fit, exactly as a selection round pays.
        let shared = SharedForecaster::with_history(
            ep_trace.clone(),
            inc_cfg,
            Some(hist.clone()),
        );
        let mut acc = 0.0;
        for &w in &omegas {
            let mut h = shared.handle();
            for t in 0..slots {
                h.observe(t, ep_trace.price_at(t), ep_trace.avail_at(t));
                acc += h.predict(w).price[0];
            }
        }
        acc
    });
    println!("{}", r_layer_cached.line());
    report.result("forecasting", &r_layer_cached);
    let layer_speedup = report.speedup(
        "ARIMA 112-policy forecast sweep",
        r_layer_batch.mean_us(),
        r_layer_cached.mean_us(),
    );
    println!("speedup: {layer_speedup:.1}x (shared cache over per-policy batch)");
    assert!(
        layer_speedup >= 10.0,
        "PERF TARGET MISSED: cached pool forecasts only {layer_speedup:.1}x over per-policy batch"
    );

    section("L3: 112-policy counterfactual job (ARIMA predictors)");
    // End-to-end: full episodes, predictor cost included. Results are
    // bit-identical between the two paths (tests/forecast_properties.rs).
    let env_batch = PolicyEnv::new(PredictorKind::Arima(batch_cfg), ep_trace.clone(), 3)
        .with_history(hist.clone());
    let r_ep_batch = bench("ARIMA sweep (per-policy batch)", 1, 3, || {
        let mut total = 0.0;
        for s in &pool {
            let mut p = s.build(&env_batch);
            total += run_episode(&j, &ep_trace, &models, p.as_mut()).utility;
        }
        total
    });
    println!("{}", r_ep_batch.line());
    report.result("episodes", &r_ep_batch);
    let r_ep_cached = bench("ARIMA sweep (shared cache, cold)", 1, 5, || {
        let env = PolicyEnv::new(PredictorKind::Arima(inc_cfg), ep_trace.clone(), 3)
            .with_history(hist.clone())
            .with_shared_forecasts();
        let mut total = 0.0;
        for s in &pool {
            let mut p = s.build(&env);
            total += run_episode(&j, &ep_trace, &models, p.as_mut()).utility;
        }
        total
    });
    println!("{}", r_ep_cached.line());
    report.result("episodes", &r_ep_cached);
    let ep_speedup = report.speedup(
        "ARIMA 112-policy episode sweep",
        r_ep_batch.mean_us(),
        r_ep_cached.mean_us(),
    );
    println!("speedup: {ep_speedup:.1}x (episodes incl. solver time)");
    assert!(
        r_ep_cached.mean_ms() < 150.0,
        "PERF TARGET MISSED: cached ARIMA sweep {} ms > 150 ms",
        r_ep_cached.mean_ms()
    );
    assert!(
        ep_speedup >= 2.0,
        "PERF TARGET MISSED: cached ARIMA episode sweep only {ep_speedup:.1}x over batch"
    );

    section("fleet: 112-candidate selection round (delta vs full replay)");
    // One contended selection round: the fleet is simulated live once
    // with the incumbent, then every one of the 112 pool candidates is
    // scored in the learner's slot while the committed background
    // replays. The baseline re-steps all 48 background jobs through the
    // whole fleet horizon per candidate (`run_with_override`); the delta
    // engine compacts the background once and charges each candidate
    // only for the slots where it diverges from the incumbent — in
    // particular, background jobs with longer deadlines and staggered
    // arrivals (the realistic churning-fleet shape) cost it nothing.
    let sel_job = Job::paper_reference();
    let sel_trace = TraceGenerator::calibrated().generate(31).slice_from(55);
    let sel_env = PolicyEnv::new(
        PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
        sel_trace.clone(),
        17,
    );
    let roster = spotfine::fleet::sweep::fleet_roster();
    let sel_bg: Vec<spotfine::fleet::FleetJobSpec> = (0..48)
        .map(|k| {
            let job = Job {
                workload: 70.0 + 4.0 * (k % 8) as f64,
                deadline: 10 + (k % 5) * 5,
                n_min: 1,
                n_max: 12,
                value: 150.0,
                gamma: 1.5,
            };
            spotfine::fleet::FleetJobSpec::new(
                job,
                roster[k % roster.len()],
                PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            )
            .with_seed(900 + k as u64)
            .with_tier(spotfine::fleet::Tier::cycle(k))
            .in_region(k % 6)
            .arriving_at((k % 4) * 3)
        })
        .collect();
    let mk_round = || FleetContendedEvaluator::new(sel_bg.clone(), 6);
    {
        // Correctness gate before timing: the two engines must agree
        // bit-for-bit on the whole pool.
        let mut delta = mk_round();
        let mut full = mk_round().with_full_replay();
        assert_eq!(
            delta.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env),
            full.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env),
            "delta replay diverged from full replay"
        );
    }
    let r_round_full = bench("selection round, full replay (48 bg jobs)", 1, 5, || {
        let mut ev = mk_round().with_full_replay();
        ev.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env)
            .iter()
            .sum::<f64>()
    });
    println!("{}", r_round_full.line());
    report.result("fleet", &r_round_full);
    let r_round_delta = bench("selection round, delta replay (48 bg jobs)", 2, 10, || {
        let mut ev = mk_round();
        ev.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env)
            .iter()
            .sum::<f64>()
    });
    println!("{}", r_round_delta.line());
    report.result("fleet", &r_round_delta);
    let round_speedup = report.speedup(
        "fleet selection round (112 candidates)",
        r_round_full.mean_us(),
        r_round_delta.mean_us(),
    );
    println!("speedup: {round_speedup:.1}x (delta replay over full fleet replay)");
    assert!(
        round_speedup >= 5.0,
        "PERF TARGET MISSED: delta replay only {round_speedup:.1}x over full fleet replay"
    );

    section("fleet: region-aware (policy-driven) migration round");
    // The same contended round under `--migration policy`: region-aware
    // AHAP candidates additionally price every candidate region's
    // forecast window per slot and may emit migration intents (which
    // join the delta engine's fork key). Gate on bit-identity with the
    // full-replay engine first — the fig13_migration bench covers the
    // utility claim; this records the migration path's perf trajectory.
    let mk_policy_round = || {
        FleetContendedEvaluator::new(sel_bg.clone(), 6)
            .with_migration_mode(MigrationMode::Policy)
    };
    {
        let mut delta = mk_policy_round();
        let mut full = mk_policy_round().with_full_replay();
        assert_eq!(
            delta.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env),
            full.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env),
            "policy-migration delta replay diverged from full replay"
        );
    }
    let r_round_policy =
        bench("selection round, delta replay, policy migration", 2, 10, || {
            let mut ev = mk_policy_round();
            ev.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env)
                .iter()
                .sum::<f64>()
        });
    println!("{}", r_round_policy.line());
    report.result("fleet", &r_round_policy);

    section("obs: recorder overhead on the contended selection round");
    // Correctness gate first: a live recorder must not move a single
    // bit of the utility vector (tests/obs_properties.rs covers the
    // full FleetResult; this pins the bench's own workload).
    {
        let mut plain = mk_round();
        let mut traced = mk_round().with_recorder(Recorder::enabled());
        assert_eq!(
            plain.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env),
            traced.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env),
            "tracing perturbed the selection round"
        );
    }
    // Zero-overhead-when-off, asserted: the same 112-candidate round
    // with an explicitly attached *disabled* recorder must cost within
    // 2% of the untraced measurement above. Min-to-min is the stable
    // comparison for a wallclock bench (means absorb scheduler noise);
    // re-measure up to 3 times before declaring a regression.
    let obs_off_name = "selection round, disabled recorder (obs off)";
    let run_off = || {
        bench(obs_off_name, 2, 10, || {
            let mut ev = mk_round().with_recorder(Recorder::disabled());
            ev.utilities(&pool, &sel_job, &sel_trace, &models, &sel_env)
                .iter()
                .sum::<f64>()
        })
    };
    let mut r_round_off = run_off();
    let mut off_ratio = r_round_off.min_ns / r_round_delta.min_ns;
    for _ in 0..2 {
        if off_ratio <= 1.02 {
            break;
        }
        r_round_off = run_off();
        off_ratio = r_round_off.min_ns / r_round_delta.min_ns;
    }
    println!("{}", r_round_off.line());
    report.result("obs", &r_round_off);
    println!(
        "obs-off overhead: {:+.2}% (min-to-min vs the untraced round)",
        100.0 * (off_ratio - 1.0)
    );
    assert!(
        off_ratio <= 1.02,
        "PERF TARGET MISSED: disabled recorder adds {:.2}% > 2% to the \
         selection round",
        100.0 * (off_ratio - 1.0)
    );
    // Informational: what tracing costs when it is actually on (ring
    // pushes + the deterministic merge in finish()).
    let r_round_on =
        bench("selection round, enabled recorder (obs on)", 2, 10, || {
            let obs = Recorder::enabled();
            let mut ev = mk_round().with_recorder(obs.clone());
            let total = ev
                .utilities(&pool, &sel_job, &sel_trace, &models, &sel_env)
                .iter()
                .sum::<f64>();
            let log = obs.finish().expect("enabled recorder yields a log");
            total + log.events as f64
        });
    println!("{}", r_round_on.line());
    report.result("obs", &r_round_on);

    section("L2/L1: PJRT train step (needs artifacts)");
    let dir = std::path::PathBuf::from("artifacts");
    if spotfine::runtime::artifact::ArtifactBundle::present(&dir) {
        let client = spotfine::runtime::client::RuntimeClient::cpu().unwrap();
        let bundle = spotfine::runtime::artifact::ArtifactBundle::load(&dir).unwrap();
        let exec = spotfine::runtime::executable::TrainStepExec::compile(&client, bundle).unwrap();
        let mut trainer = spotfine::train::trainer::Trainer::new(
            exec,
            spotfine::train::trainer::TrainerConfig::default(),
        )
        .unwrap();
        let r = bench("grad+apply step (1 shard)", 1, 5, || {
            trainer.step_parallel(1).unwrap().loss
        });
        println!("{}", r.line());
        report.result("pjrt", &r);
        let r = bench("grad+apply step (4 shards)", 1, 5, || {
            trainer.step_parallel(4).unwrap().loss
        });
        println!("{}", r.line());
        report.result("pjrt", &r);
    } else {
        println!("SKIP: artifacts not built");
    }

    match report.write("BENCH_hotpaths.json") {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write BENCH_hotpaths.json: {e}"),
    }

    if let Some(path) = baseline_path {
        // Ratios are informational (hardware varies; the absolute
        // budgets are asserted above) — lost coverage is not. The diff
        // is section-scoped, so other binaries' sections in the shared
        // baseline (e.g. fig14's `fleet100k`) are not this run's
        // obligation.
        spotfine::util::bench::diff_against_baseline(&report, &path);
    }

    println!(
        "summary: greedy solve {:.1} µs/decision — the planner runs ~10⁶× \
         faster than the 30-min slot it schedules; incremental+shared ARIMA \
         serves the 112-policy pool at {:.1}x the per-policy batch cost; \
         delta replay scores a 112-candidate contended selection round at \
         {:.1}x the full-fleet-replay cost.",
        greedy_us, layer_speedup, round_speedup,
    );
}
