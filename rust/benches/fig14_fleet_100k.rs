//! Fig. 14 (ours) — production-scale fleet: the event-driven stepper
//! ([`spotfine::fleet::events`]) simulating ~100k churning jobs across
//! 64 regions over the full horizon, in seconds. Three claims, each
//! gated on correctness before it is timed:
//!
//! - the arithmetic water-fill is bit-identical to the historical
//!   one-unit-per-round loop and beats it by orders of magnitude at
//!   100k-unit capacity;
//! - the event-driven stepper (1 thread and max threads) reproduces the
//!   dense reference stepper's `FleetResult` bit-for-bit on the full
//!   churning fleet;
//! - the full-scale run completes within a seconds-scale wall-clock
//!   budget (asserted).
//!
//! `--smoke` runs the same benches (same names, so baseline coverage
//! checks line up) on a small fleet — the CI rot check. Results are
//! recorded to `BENCH_fleet100k.json` under the `fleet100k` section;
//! pass `--baseline <path>` (CI points it at the committed repo-root
//! `BENCH_hotpaths.json`) to diff against the recorded trajectory.

use spotfine::fleet::capacity::{
    water_fill, water_fill_reference, SpotRequest, Tier,
};
use spotfine::fleet::{available_threads, FleetScenario};
use spotfine::util::bench::{
    bench, diff_against_baseline, section, JsonReport,
};
use spotfine::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let baseline_path = argv
        .iter()
        .position(|a| a == "--baseline")
        .and_then(|i| argv.get(i + 1).cloned());
    let mut report = JsonReport::new("fig14_fleet_100k");
    println!(
        "=== Fig. 14: event-driven fleet at 100k-job scale{} ===",
        if smoke { "  [smoke]" } else { "" }
    );

    // --- Water-fill: arithmetic fair share vs the unit loop. ---------
    // A contended region-slot at production scale: 2000 requests over a
    // 100k-unit capacity (Σ demand ≈ 130k > cap, so the block-walk and
    // the partial round are both exercised). Bit-identity across the
    // cap range is the gate; the timing is the headline.
    section("fleet100k: water-fill, arithmetic vs unit-loop reference");
    let mut wrng = Rng::new(0x0F19_0014);
    let reqs: Vec<SpotRequest> = (0..2000)
        .map(|k| SpotRequest {
            job: k,
            tier: Tier::cycle(k),
            want: wrng.int_range(0, 120) as u32,
            held: wrng.int_range(0, 60) as u32,
        })
        .collect();
    let demands: Vec<u32> = reqs.iter().map(|r| r.held.max(r.want)).collect();
    for cap in [0u32, 1, 999, 10_000, 100_000, 1_000_000] {
        assert_eq!(
            water_fill(cap, &reqs, &demands),
            water_fill_reference(cap, &reqs, &demands),
            "arithmetic water-fill diverged from its reference at cap={cap}"
        );
    }
    let cap = 100_000u32;
    let r_ref = bench("water-fill 2000 req / cap 100k (unit loop)", 3, 50, || {
        water_fill_reference(cap, &reqs, &demands)
    });
    println!("{}", r_ref.line());
    report.result("fleet100k", &r_ref);
    let r_arith = bench("water-fill 2000 req / cap 100k (arithmetic)", 10, 200, || {
        water_fill(cap, &reqs, &demands)
    });
    println!("{}", r_arith.line());
    report.result("fleet100k", &r_arith);
    let wf_speedup = report.speedup(
        "water-fill arithmetic over unit loop",
        r_ref.mean_us(),
        r_arith.mean_us(),
    );
    println!("speedup: {wf_speedup:.1}x (arithmetic over unit loop)");
    assert!(
        wf_speedup >= 5.0,
        "PERF TARGET MISSED: arithmetic water-fill only {wf_speedup:.1}x \
         over the unit loop at cap 100k"
    );

    // --- The churning fleet: dense vs event-driven, bit-for-bit. -----
    // Full mode: 4000 base jobs + Poisson(9600)/slot churn over the
    // 10-slot base horizon ≈ 100k jobs across 64 regions, horizon 19.
    // Smoke keeps the same shape (and bench names) at 1/64 the churn.
    let (base_jobs, n_regions, churn) =
        if smoke { (400, 8, 150.0) } else { (4000, 64, 9600.0) };
    let sc = FleetScenario::new(base_jobs, n_regions, 0xF1EE7).with_churn(churn);
    let (engine, specs) = sc.build();
    let threads = available_threads();
    section("fleet100k: dense vs event-driven stepper");
    println!(
        "fleet: {} jobs ({base_jobs} base + churn) x {n_regions} regions, \
         {threads} host threads",
        specs.len(),
    );
    if !smoke {
        assert!(
            specs.len() >= 95_000,
            "churn sizing regressed: only {} jobs materialized",
            specs.len()
        );
    }

    let mut out_dense = None;
    let r_dense = bench("fleet churn, dense stepper", 0, 1, || {
        out_dense = Some(engine.clone().with_dense_stepper().run(&specs));
    });
    println!("{}", r_dense.line());
    report.result("fleet100k", &r_dense);
    let mut out_e1 = None;
    let r_e1 = bench("fleet churn, event stepper (1 thread)", 0, 1, || {
        out_e1 = Some(engine.clone().with_threads(1).run(&specs));
    });
    println!("{}", r_e1.line());
    report.result("fleet100k", &r_e1);
    let mut out_en = None;
    let r_en = bench("fleet churn, event stepper (max threads)", 0, 1, || {
        out_en = Some(engine.clone().with_threads(threads).run(&specs));
    });
    println!("{}", r_en.line());
    report.result("fleet100k", &r_en);

    // The correctness gate: one result, three steppers.
    let dense = out_dense.expect("dense run recorded");
    let e1 = out_e1.expect("event run recorded");
    let en = out_en.expect("threaded event run recorded");
    assert_eq!(
        e1, dense,
        "event stepper (1 thread) diverged from the dense reference"
    );
    assert_eq!(
        en, dense,
        "event stepper ({threads} threads) diverged from the dense reference"
    );
    println!("bit-identity: dense == event(1) == event({threads})  [ok]");

    let engine_speedup = report.speedup(
        "event stepper (max threads) over dense",
        r_dense.mean_us(),
        r_en.mean_us(),
    );
    let job_slots: usize =
        dense.jobs.iter().map(|j| j.episode.decisions.len()).sum();
    let secs = r_en.mean_ns / 1e9;
    println!(
        "event stepper: {} job-slots over {} slots in {secs:.2} s \
         ({:.0} job-slots/s); {engine_speedup:.2}x over dense",
        job_slots,
        dense.slots,
        job_slots as f64 / secs.max(1e-9),
    );
    if !smoke {
        // The scale target: the full ~100k-job fleet simulates in
        // seconds, not minutes.
        assert!(
            r_en.mean_ns < 60e9,
            "PERF TARGET MISSED: 100k-job fleet took {secs:.1} s > 60 s"
        );
    }

    match report.write("BENCH_fleet100k.json") {
        Ok(path) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write BENCH_fleet100k.json: {e}"),
    }
    if let Some(path) = baseline_path {
        // Section-scoped: only `fleet100k` entries in the shared
        // baseline are this bench's coverage obligation.
        diff_against_baseline(&report, &path);
    }
}
