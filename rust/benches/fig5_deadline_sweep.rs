//! Fig. 5 — utility vs job deadline. Paper headline at d = 10: AHAP
//! improves utility by 49.0% / 54.8% / 33.4% / 23.2% over OD-Only / MSU /
//! UP / AHANP. We reproduce the *shape*: AHAP best at every deadline,
//! all gaps positive, tight deadlines hurting spot-heavy baselines most.

#[path = "sweep_common.rs"]
mod sweep_common;

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::GeneratorConfig;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};
use sweep_common::{evaluate_point, improvement};

fn main() {
    println!("=== Fig. 5: utility vs deadline ===");
    let deadlines = [6usize, 8, 10, 12, 14];
    let n_jobs = 120;
    let noise = NoiseSpec::fixed_mag_uniform(0.1);
    let models = Models::paper_default();

    let mut table = Table::new(&[
        "deadline", "OD-Only", "MSU", "UP", "AHANP", "AHAP (best)",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig5_deadline.csv",
        &["deadline", "group", "utility", "norm_utility", "misses"],
    )
    .expect("csv");
    let mut at10 = None;
    for &d in &deadlines {
        // The paper's deadline sweep varies d around the reference job
        // (L = 80: LLaMA2-7B LoRA on 20M tokens); keep workloads near
        // that reference so tight deadlines stress scheduling rather
        // than raw feasibility.
        let jobs = JobGenerator {
            deadline: d,
            workload_lo: 75.0,
            workload_hi: 85.0,
            ..JobGenerator::default()
        };
        let scores = evaluate_point(
            &GeneratorConfig::default(),
            &jobs,
            &models,
            noise,
            n_jobs,
            42,
        );
        let get = |n: &str| scores.iter().find(|s| s.name == n).unwrap();
        table.row(&[
            d.to_string(),
            f(get("OD-Only").utility, 1),
            f(get("MSU").utility, 1),
            f(get("UP").utility, 1),
            f(get("AHANP").utility, 1),
            f(get("AHAP").utility, 1),
        ]);
        for s in &scores {
            csv.row(&[
                d.to_string(),
                s.name.to_string(),
                format!("{:.4}", s.utility),
                format!("{:.4}", s.norm_utility),
                s.misses.to_string(),
            ]);
        }
        if d == 10 {
            at10 = Some(scores);
        }
    }
    table.print();
    csv.finish().expect("csv");

    let scores = at10.expect("d=10 evaluated");
    println!("\nAHAP improvement at d = 10 (paper → measured):");
    for (name, paper) in
        [("OD-Only", 49.0), ("MSU", 54.8), ("UP", 33.4), ("AHANP", 23.2)]
    {
        let got = improvement(&scores, name);
        println!("  vs {name:<8} paper +{paper:.1}%   measured {got:+.1}%");
        assert!(
            got > 0.0,
            "shape violated: AHAP must beat {name} at the reference deadline"
        );
    }
    println!("\nshape OK: AHAP dominates all baselines; wrote results/fig5_deadline.csv");
}
