//! Fig. 9 — convergence of the Online Policy Selection algorithm under
//! the four prediction-noise regimes (Mag-Dep./Fixed-Mag. ×
//! Uniform/Heavy-Tail), plus the fixed-hyperparameter pool ablations
//! (pin v = 1 / pin σ = 0.9). Also sanity-checks the two theorems:
//! Thm. 2 (regret ≤ √(2K ln M)) and Thm. 1 (AHAP's gap to OPT grows
//! with prediction error).

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::TraceGenerator;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::offline::solve_offline;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{
    ahap_pool_fixed_sigma, ahap_pool_fixed_v, paper_pool, PolicyEnv, PolicySpec,
    PredictorKind,
};
use spotfine::sched::selector::{run_selection, SelectionConfig};
use spotfine::sched::simulate::run_episode;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::rng::Rng;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

fn main() {
    let k_jobs = 400; // paper: 1000; compressed for the bench budget
    let jobs = JobGenerator::default();
    let models = Models::paper_default();
    let gen = TraceGenerator::calibrated();

    println!("=== Fig. 9: online policy selection under prediction noise ===");
    let regimes = [
        NoiseSpec::mag_dep_uniform(0.3),
        NoiseSpec::fixed_mag_uniform(0.3),
        NoiseSpec::mag_dep_heavy(0.3),
        NoiseSpec::fixed_mag_heavy(0.3),
    ];
    let pools: Vec<(&str, Vec<PolicySpec>)> = vec![
        ("full pool (112)", paper_pool()),
        ("fixed v=1 (35)", ahap_pool_fixed_v(1)),
        ("fixed σ=0.9 (15)", ahap_pool_fixed_sigma(0.9)),
    ];

    let mut table = Table::new(&[
        "noise regime", "pool", "converged policy", "mean u", "regret", "bound",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig9_convergence.csv",
        &["regime", "pool", "job", "expected_norm_utility", "regret"],
    )
    .expect("csv");

    for noise in &regimes {
        for (pool_name, specs) in &pools {
            let out = run_selection(
                specs,
                &jobs,
                &models,
                &gen,
                |_| PredictorKind::Noisy(*noise),
                &SelectionConfig { k_jobs, seed: 7, snapshot_every: 0 },
            );
            let regret = *out.regret.last().unwrap();
            let bound = out.regret_bound();
            assert!(
                regret <= bound + 1e-9,
                "Thm. 2 violated: regret {regret} > bound {bound}"
            );
            table.row(&[
                noise.label(),
                pool_name.to_string(),
                specs[out.converged_to].label(),
                f(stats::mean(&out.expected), 4),
                f(regret, 2),
                f(bound, 2),
            ]);
            // convergence curve (running mean of expected utility)
            let mut running = 0.0;
            for (k, e) in out.expected.iter().enumerate() {
                running += e;
                if (k + 1) % 20 == 0 {
                    csv.row(&[
                        noise.label(),
                        pool_name.to_string(),
                        (k + 1).to_string(),
                        format!("{:.5}", running / (k + 1) as f64),
                        format!("{:.4}", out.regret[k]),
                    ]);
                }
            }
        }
    }
    table.print();
    csv.finish().expect("csv");

    // Thm. 1 sanity: AHAP's mean gap to the offline OPT widens as the
    // prediction error grows.
    println!("\nThm. 1 sanity: AHAP gap to OPT vs prediction error");
    let spec = PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 };
    let mut gaps = Vec::new();
    for level in [0.0, 0.3, 1.0, 2.0] {
        let mut rng = Rng::new(5);
        let mut gap = 0.0;
        let n = 80;
        for k in 0..n {
            let job = jobs.sample(&mut rng);
            let trace = gen
                .generate(900 + k)
                .slice_from(rng.index(400));
            let opt = solve_offline(&job, &trace, &models, 0.1).utility;
            let env = PolicyEnv::new(
                PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(level)),
                trace.clone(),
                k,
            );
            let mut p = spec.build(&env);
            let r = run_episode(&job, &trace, &models, p.as_mut());
            gap += opt - r.utility;
        }
        gap /= n as f64;
        println!("  error {:>4.0}% → mean OPT−AHAP gap {:.2}", level * 100.0, gap);
        gaps.push(gap);
    }
    assert!(
        gaps.last().unwrap() > gaps.first().unwrap(),
        "Thm. 1 shape violated: gap must grow with prediction error"
    );
    println!("\nshape OK: regret under bound in all regimes; gap grows with error.");
    println!("wrote results/fig9_convergence.csv");
}
