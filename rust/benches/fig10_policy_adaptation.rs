//! Fig. 10 — policy-weight dynamics under changing prediction quality:
//! four phases (Fixed-Mag.+Uniform 10% → Fixed-Mag.+Heavy-Tail 30% →
//! Fixed-Mag.+Uniform 50% → 200%), pool of 105 AHAP + 7 AHANP policies
//! indexed 1..112. The paper's claim: the selector re-converges to a new
//! optimal policy after every phase change.

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::TraceGenerator;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{paper_pool, PredictorKind};
use spotfine::sched::selector::{run_selection, SelectionConfig};
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};

fn main() {
    println!("=== Fig. 10: policy-weight dynamics across noise phases ===");
    // Paper: 3600 jobs over 4 phases; compressed 3× for the bench budget.
    let phase_len = 300usize;
    let phases = [
        NoiseSpec::fixed_mag_uniform(0.1),
        NoiseSpec::fixed_mag_heavy(0.3),
        NoiseSpec::fixed_mag_uniform(0.5),
        NoiseSpec::fixed_mag_uniform(2.0),
    ];
    let k_jobs = phase_len * phases.len();
    let specs = paper_pool();
    let out = run_selection(
        &specs,
        &JobGenerator::default(),
        &Models::paper_default(),
        &TraceGenerator::calibrated(),
        |k| PredictorKind::Noisy(phases[(k / phase_len).min(phases.len() - 1)]),
        &SelectionConfig { k_jobs, seed: 13, snapshot_every: 25 },
    );

    // Heatmap CSV: (job, policy index 1..112, weight).
    let mut csv = CsvWriter::create(
        "results/fig10_weights.csv",
        &["job", "policy_index", "weight"],
    )
    .expect("csv");
    for (k, w) in &out.snapshots {
        for (i, wi) in w.iter().enumerate() {
            if *wi > 1e-4 {
                csv.row(&[k.to_string(), (i + 1).to_string(), format!("{wi:.6}")]);
            }
        }
    }
    csv.finish().expect("csv");

    // Per-phase winner: average the weights over the phase's second half
    // (after re-convergence).
    let mut table = Table::new(&[
        "phase", "noise", "top policy (late-phase weight mass)", "mass",
    ]);
    let mut winners = Vec::new();
    for (pi, noise) in phases.iter().enumerate() {
        let lo = pi * phase_len + phase_len / 2;
        let hi = (pi + 1) * phase_len;
        let snaps: Vec<&Vec<f64>> = out
            .snapshots
            .iter()
            .filter(|(k, _)| *k > lo && *k <= hi)
            .map(|(_, w)| w)
            .collect();
        assert!(!snaps.is_empty(), "no snapshots in phase {pi}");
        let mut mean_w = vec![0.0; specs.len()];
        for w in &snaps {
            for (m, wi) in mean_w.iter_mut().zip(w.iter()) {
                *m += wi;
            }
        }
        for m in mean_w.iter_mut() {
            *m /= snaps.len() as f64;
        }
        let (best, mass) = mean_w
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, m)| (i, *m))
            .unwrap();
        table.row(&[
            (pi + 1).to_string(),
            noise.label(),
            format!("#{} {}", best + 1, specs[best].label()),
            f(mass, 3),
        ]);
        winners.push(best);
    }
    table.print();

    // Shape: the selector adapts — the winning policy is not constant
    // across all four phases (good predictions favour different (ω,v,σ)
    // than catastrophic ones; 200% noise should push toward AHANP or
    // conservative AHAP configs).
    let all_same = winners.iter().all(|&w| w == winners[0]);
    assert!(
        !all_same,
        "shape violated: the optimal policy must shift across noise phases"
    );
    println!(
        "\nregret {:.2} ≤ bound {:.2}; winners shift across phases — shape OK.",
        out.regret.last().unwrap(),
        out.regret_bound()
    );
    println!("wrote results/fig10_weights.csv");
}
