//! Ablation — CHC's commitment level v against its two degenerate ends,
//! which the paper rejects in §IV-A when motivating CHC:
//!
//!   RHC  (v = 1):   most responsive, "sensitive to prediction errors";
//!   AFHC (v = ω+1): most stable, "suffers from error accumulation";
//!   CHC  (1 < v < ω+1): the tunable middle AHAP builds on.
//!
//! Sweeps v at fixed ω = 4 across noise levels. **Measured finding**
//! (recorded in EXPERIMENTS.md): on our market, v = 1 dominates at every
//! noise level and higher commitment degrades monotonically — stale
//! plans embed outdated *progress* assumptions (a systematic error that
//! averaging amplifies rather than cancels), unlike the i.i.d.
//! prediction noise CHC's averaging is designed to smooth. This is
//! consistent with the Fig. 9 selector always converging to v = 1
//! configurations, and is itself an argument for the paper's design of
//! learning v online from the pool instead of fixing it a priori.
//!
//! Run: cargo bench --bench ablation_chc

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::TraceGenerator;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{PolicyEnv, PolicySpec, PredictorKind};
use spotfine::sched::simulate::run_episode;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::rng::Rng;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

fn main() {
    println!("=== Ablation: CHC commitment level (RHC / CHC / AFHC) ===");
    let omega = 4usize;
    let sigma = 0.7;
    let n_jobs = 150;
    let jobs = JobGenerator::default();
    let models = Models::paper_default();
    let gen = TraceGenerator::calibrated();
    let noise_levels = [0.0f64, 0.3, 1.0, 2.0];
    let vs: Vec<usize> = (1..=omega + 1).collect();

    let mut table = Table::new(&[
        "noise", "v=1 (RHC)", "v=2", "v=3", "v=4", "v=5 (AFHC)", "best v",
    ]);
    let mut csv = CsvWriter::create(
        "results/ablation_chc.csv",
        &["noise", "v", "mean_utility", "std"],
    )
    .expect("csv");

    for &level in &noise_levels {
        let mut means = Vec::new();
        for &v in &vs {
            let spec = PolicySpec::Ahap { omega, v, sigma };
            let mut utils = Vec::new();
            let mut rng = Rng::new(77);
            for k in 0..n_jobs {
                let job = jobs.sample(&mut rng);
                let trace = gen
                    .generate(500 + k as u64)
                    .slice_from(rng.index(400));
                let env = PolicyEnv::new(
                    PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(level)),
                    trace.clone(),
                    k as u64,
                );
                let mut p = spec.build(&env);
                utils.push(run_episode(&job, &trace, &models, p.as_mut()).utility);
            }
            let m = stats::mean(&utils);
            csv.row(&[
                format!("{level:.1}"),
                v.to_string(),
                format!("{m:.4}"),
                format!("{:.4}", stats::std_dev(&utils)),
            ]);
            means.push(m);
        }
        let best_v = means
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| vs[i])
            .unwrap();
        table.row(&[
            format!("{:.0}%", level * 100.0),
            f(means[0], 1),
            f(means[1], 1),
            f(means[2], 1),
            f(means[3], 1),
            f(means[4], 1),
            best_v.to_string(),
        ]);
    }
    table.print();
    csv.finish().expect("csv");
    println!("\nfinding: v = 1 (RHC-like responsiveness) dominates on this market —");
    println!("stale plans carry outdated progress state, a systematic error that");
    println!("averaging amplifies. Matches Fig. 9's selector converging to v = 1.");
    println!("wrote results/ablation_chc.csv");
}
