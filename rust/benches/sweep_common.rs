//! Shared sweep machinery for the Fig. 5–8 benches: evaluate the policy
//! roster (baselines + best-in-pool AHAP/AHANP, mirroring the paper's
//! "the selected optimal policy is always the better of the two") over
//! sampled jobs and report mean normalized utility.

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::{GeneratorConfig, TraceGenerator};
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{PolicyEnv, PolicySpec, PredictorKind};
use spotfine::sched::simulate::run_episode;
use spotfine::util::rng::Rng;

/// The comparison roster: named groups of candidate specs; each group's
/// score is the best mean utility across its members (the paper's
/// adaptive selection picks per-group winners).
pub fn roster() -> Vec<(&'static str, Vec<PolicySpec>)> {
    let ahap: Vec<PolicySpec> = [
        (1usize, 1usize, 0.9f64),
        (2, 1, 0.5),
        (2, 1, 0.9),
        (3, 1, 0.7),
        (4, 2, 0.7),
        (5, 1, 0.5),
        (5, 2, 0.9),
    ]
    .iter()
    .map(|&(omega, v, sigma)| PolicySpec::Ahap { omega, v, sigma })
    .collect();
    let ahanp: Vec<PolicySpec> = [0.4, 0.5, 0.7, 0.9]
        .iter()
        .map(|&sigma| PolicySpec::Ahanp { sigma })
        .collect();
    vec![
        ("OD-Only", vec![PolicySpec::OdOnly]),
        ("MSU", vec![PolicySpec::Msu]),
        ("UP", vec![PolicySpec::UniformProgress]),
        ("AHANP", ahanp),
        ("AHAP", ahap),
    ]
}

/// One sweep point's outcome for a group.
#[derive(Debug, Clone)]
pub struct GroupScore {
    pub name: &'static str,
    /// Mean raw utility of the best member.
    pub utility: f64,
    /// Mean normalized utility of the best member.
    pub norm_utility: f64,
    /// Deadline misses of the best member.
    pub misses: usize,
}

/// Evaluate every group on `n_jobs` sampled jobs under the given market
/// generator config and noise level; deterministic in `seed`.
pub fn evaluate_point(
    gen_cfg: &GeneratorConfig,
    jobs_cfg: &JobGenerator,
    models: &Models,
    noise: NoiseSpec,
    n_jobs: usize,
    seed: u64,
) -> Vec<GroupScore> {
    let gen = TraceGenerator::new(gen_cfg.clone());
    let groups = roster();
    // per member: (sum utility, sum norm, misses)
    let mut acc: Vec<Vec<(f64, f64, usize)>> = groups
        .iter()
        .map(|(_, m)| vec![(0.0, 0.0, 0usize); m.len()])
        .collect();
    let mut rng = Rng::new(seed);
    for k in 0..n_jobs {
        let job = jobs_cfg.sample(&mut rng);
        let trace = gen
            .generate(seed ^ (k as u64).wrapping_mul(0x9E37_79B9))
            .slice_from(rng.index(400));
        let env = PolicyEnv::new(PredictorKind::Noisy(noise), trace.clone(), seed ^ k as u64);
        for (gi, (_, members)) in groups.iter().enumerate() {
            for (mi, spec) in members.iter().enumerate() {
                let mut p = spec.build(&env);
                let r = run_episode(&job, &trace, models, p.as_mut());
                acc[gi][mi].0 += r.utility;
                acc[gi][mi].1 +=
                    job.normalize_utility(r.utility, models.on_demand_price);
                if !r.on_time {
                    acc[gi][mi].2 += 1;
                }
            }
        }
    }
    groups
        .iter()
        .enumerate()
        .map(|(gi, (name, _))| {
            let best = acc[gi]
                .iter()
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap();
            GroupScore {
                name,
                utility: best.0 / n_jobs as f64,
                norm_utility: best.1 / n_jobs as f64,
                misses: best.2,
            }
        })
        .collect()
}

/// Percentage improvement of AHAP over another group.
pub fn improvement(scores: &[GroupScore], over: &str) -> f64 {
    let ahap = scores.iter().find(|s| s.name == "AHAP").unwrap().utility;
    let other = scores.iter().find(|s| s.name == over).unwrap().utility;
    100.0 * (ahap - other) / other.abs().max(1e-9)
}
