//! Fig. 6 — impact of reconfiguration overhead, swept as network
//! bandwidth 100 → 800 Mbps (which sets μ via checkpoint-transfer time).
//! Paper shape: all policies degrade as bandwidth shrinks **except
//! AHANP**, whose stability-first case analysis avoids reconfiguration.

#[path = "sweep_common.rs"]
mod sweep_common;

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::GeneratorConfig;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::sched::throughput::ReconfigModel;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};
use sweep_common::evaluate_point;

fn main() {
    println!("=== Fig. 6: utility vs reconfiguration overhead (bandwidth) ===");
    let bandwidths = [100.0f64, 200.0, 400.0, 800.0];
    let n_jobs = 120;
    let noise = NoiseSpec::fixed_mag_uniform(0.1);
    let jobs = JobGenerator::default();

    let mut table = Table::new(&[
        "bandwidth (Mbps)", "μ₁", "OD-Only", "MSU", "UP", "AHANP", "AHAP",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig6_bandwidth.csv",
        &["bandwidth_mbps", "group", "utility", "misses"],
    )
    .expect("csv");
    let mut series: Vec<(f64, Vec<sweep_common::GroupScore>)> = Vec::new();
    for &bw in &bandwidths {
        let mut models = Models::paper_default();
        models.reconfig = ReconfigModel::from_bandwidth_mbps(bw, 30.0);
        let scores = evaluate_point(
            &GeneratorConfig::default(),
            &jobs,
            &models,
            noise,
            n_jobs,
            42,
        );
        let get = |n: &str| scores.iter().find(|s| s.name == n).unwrap();
        table.row(&[
            format!("{bw:.0}"),
            f(models.reconfig.mu_up, 2),
            f(get("OD-Only").utility, 1),
            f(get("MSU").utility, 1),
            f(get("UP").utility, 1),
            f(get("AHANP").utility, 1),
            f(get("AHAP").utility, 1),
        ]);
        for s in &scores {
            csv.row(&[
                format!("{bw:.0}"),
                s.name.to_string(),
                format!("{:.4}", s.utility),
                s.misses.to_string(),
            ]);
        }
        series.push((bw, scores));
    }
    table.print();
    csv.finish().expect("csv");

    // Shape: AHANP's degradation from 800 → 100 Mbps is the smallest
    // among spot-using policies.
    let drop = |name: &str| {
        let lo = series[0].1.iter().find(|s| s.name == name).unwrap().utility;
        let hi = series[3].1.iter().find(|s| s.name == name).unwrap().utility;
        hi - lo
    };
    let ahanp_drop = drop("AHANP");
    for other in ["MSU", "AHAP"] {
        println!(
            "degradation 800→100 Mbps: AHANP {:.1} vs {} {:.1}",
            ahanp_drop,
            other,
            drop(other)
        );
    }
    assert!(
        ahanp_drop <= drop("MSU") + 1e-9,
        "shape violated: AHANP must be the most bandwidth-robust spot policy"
    );
    println!("\nshape OK: AHANP flattest under shrinking bandwidth; wrote results/fig6_bandwidth.csv");
}
