//! Fig. 12 (ours) — isolated vs contention-aware policy selection: run
//! Algorithm 2 twice on the same job stream, once scoring candidates on
//! private markets and once inside a contended fleet (committed
//! background jobs replaying while each candidate is swapped into the
//! learner's slot), then judge both learners' final picks by their
//! *fleet* utility on held-out contended rounds.
//!
//! `--smoke` runs a single round of everything (the CI rot check for
//! this target); the full run uses 80 learning + 20 evaluation rounds.

use spotfine::fleet::{
    available_threads, run_fleet_selection, FleetContendedEvaluator,
};
use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::TraceGenerator;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::sched::pool::{PolicyEnv, PolicySpec, PredictorKind};
use spotfine::sched::selector::{
    run_selection, EpisodeEvaluator, SelectionConfig,
};
use spotfine::util::bench::{section, time_once};
use spotfine::util::csvio::CsvWriter;
use spotfine::util::rng::Rng;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

fn pool() -> Vec<PolicySpec> {
    vec![
        PolicySpec::OdOnly,
        PolicySpec::Msu,
        PolicySpec::UniformProgress,
        PolicySpec::Ahanp { sigma: 0.5 },
        PolicySpec::Ahap { omega: 1, v: 1, sigma: 0.5 },
        PolicySpec::Ahap { omega: 3, v: 1, sigma: 0.7 },
        PolicySpec::Ahap { omega: 5, v: 2, sigma: 0.9 },
    ]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rounds = if smoke { 1 } else { 80 };
    let eval_rounds = if smoke { 1 } else { 20 };
    let threads = available_threads();
    let seed = 42u64;

    println!("=== Fig. 12: isolated vs contention-aware selection ===");
    println!(
        "{rounds} learning rounds, {eval_rounds} evaluation rounds, \
         {threads} thread(s){}\n",
        if smoke { "  [smoke]" } else { "" }
    );

    let specs = pool();
    let jobs = JobGenerator::default();
    let models = Models::paper_default();
    let gen = TraceGenerator::calibrated();
    let cfg = SelectionConfig { k_jobs: rounds, seed, snapshot_every: 0 };
    let noise =
        |_: usize| PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1));

    let mut csv = CsvWriter::create(
        "results/fig12_fleet_selection.csv",
        &["learner", "converged_policy", "regret", "seconds", "fleet_utility"],
    )
    .expect("csv");

    // --- Learn both ways on the same stream. --------------------------
    section("learning");
    let (isolated, iso_secs) =
        time_once(|| run_selection(&specs, &jobs, &models, &gen, noise, &cfg));
    println!(
        "isolated:    converged to {} in {iso_secs:.2}s (regret {:.2})",
        specs[isolated.converged_to].label(),
        isolated.regret.last().unwrap()
    );

    let mut evaluator = FleetContendedEvaluator::synthetic(8, 2, seed)
        .with_threads(threads);
    let (fleet_aware, fleet_secs) = time_once(|| {
        run_fleet_selection(
            &specs, &jobs, &models, &gen, noise, &cfg, &mut evaluator,
        )
    });
    println!(
        "fleet-aware: converged to {} in {fleet_secs:.2}s (regret {:.2})",
        specs[fleet_aware.converged_to].label(),
        fleet_aware.regret.last().unwrap()
    );

    // --- Counterfactual engine: delta vs full replay. -----------------
    // One full-pool (112 candidates) contended round through both
    // engines: bit-identical utilities, very different price tags.
    section("counterfactual engine: delta vs full replay (112 candidates)");
    let engine_pool = spotfine::sched::pool::paper_pool();
    let mut engine_rng = Rng::new(seed ^ 0xD17A);
    let engine_job = jobs.sample(&mut engine_rng);
    let engine_trace = gen.generate(seed ^ 0xD17A).slice_from(60);
    let engine_env = PolicyEnv::new(
        PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
        engine_trace.clone(),
        seed ^ 0xD17A,
    );
    let mut delta_ev = FleetContendedEvaluator::synthetic(8, 2, seed)
        .with_threads(threads);
    let mut full_ev = FleetContendedEvaluator::synthetic(8, 2, seed)
        .with_threads(threads)
        .with_full_replay();
    let (u_delta, delta_secs) = time_once(|| {
        delta_ev.utilities(&engine_pool, &engine_job, &engine_trace, &models, &engine_env)
    });
    let (u_full, full_secs) = time_once(|| {
        full_ev.utilities(&engine_pool, &engine_job, &engine_trace, &models, &engine_env)
    });
    assert_eq!(u_delta, u_full, "delta replay must match full replay bit-for-bit");
    println!(
        "one 112-candidate round: delta {delta_secs:.3}s vs full {full_secs:.3}s \
         ({:.1}x)",
        full_secs / delta_secs.max(1e-9)
    );

    // --- Judge both picks by held-out *fleet* utility. ----------------
    section("held-out contended evaluation");
    let mut judge = FleetContendedEvaluator::synthetic(8, 2, seed)
        .with_threads(threads);
    let mut rng = Rng::new(seed ^ 0xE7A1_5A17);
    let mut iso_u = Vec::with_capacity(eval_rounds);
    let mut fleet_u = Vec::with_capacity(eval_rounds);
    for e in 0..eval_rounds {
        let job = jobs.sample(&mut rng);
        let full = gen.generate(0x5157 + e as u64);
        let max_off = full.len().saturating_sub(2 * job.deadline).max(1);
        let trace = full.slice_from(rng.index(max_off));
        let env = PolicyEnv::new(
            PredictorKind::Noisy(NoiseSpec::fixed_mag_uniform(0.1)),
            trace.clone(),
            0x5157 + e as u64,
        );
        let u = judge.utilities(&specs, &job, &trace, &models, &env);
        iso_u.push(u[isolated.converged_to]);
        fleet_u.push(u[fleet_aware.converged_to]);
    }
    let iso_mean = stats::mean(&iso_u);
    let fleet_mean = stats::mean(&fleet_u);

    let mut t = Table::new(&[
        "learner",
        "converged policy",
        "regret",
        "learn secs",
        "fleet utility (held-out)",
    ]);
    t.row(&[
        "isolated".into(),
        specs[isolated.converged_to].label(),
        f(*isolated.regret.last().unwrap(), 2),
        format!("{iso_secs:.2}"),
        f(iso_mean, 4),
    ]);
    t.row(&[
        "fleet-aware".into(),
        specs[fleet_aware.converged_to].label(),
        f(*fleet_aware.regret.last().unwrap(), 2),
        format!("{fleet_secs:.2}"),
        f(fleet_mean, 4),
    ]);
    t.print();
    println!(
        "\ncontention-aware learning advantage: {:+.4} normalized utility",
        fleet_mean - iso_mean
    );

    csv.row(&[
        "isolated".into(),
        specs[isolated.converged_to].label(),
        format!("{:.4}", isolated.regret.last().unwrap()),
        format!("{iso_secs:.4}"),
        format!("{iso_mean:.6}"),
    ]);
    csv.row(&[
        "fleet-aware".into(),
        specs[fleet_aware.converged_to].label(),
        format!("{:.4}", fleet_aware.regret.last().unwrap()),
        format!("{fleet_secs:.4}"),
        format!("{fleet_mean:.6}"),
    ]);
    let path = csv.finish().expect("write csv");
    println!("wrote {}", path.display());
}
