//! Fig. 2 — A100 spot-instance fluctuations over 10 days on Vast.ai:
//! (a) availability over time with a diurnal cycle, (b) price
//! distribution with median ≈ 0.6 × P90.
//!
//! Regenerated from the calibrated synthetic generator (DESIGN.md
//! substitution) across 20 seeds; the paper's headline statistics are
//! printed next to ours.

use spotfine::market::analyze::{analyze, diurnal_profile};
use spotfine::market::generator::TraceGenerator;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

fn main() {
    println!("=== Fig. 2: spot market fluctuations (10 days, 30-min slots) ===");
    let gen = TraceGenerator::calibrated();

    let mut ratios = Vec::new();
    let mut avail_means = Vec::new();
    let mut starved = Vec::new();
    let mut ac_avail = Vec::new();
    for seed in 0..20 {
        let t = gen.generate(seed);
        let s = analyze(&t);
        ratios.push(s.median_over_p90);
        avail_means.push(s.avail_mean);
        starved.push(s.starved_frac);
        ac_avail.push(s.avail_autocorr1);
    }

    let mut table = Table::new(&["statistic", "paper (Vast.ai)", "ours (20 seeds)"]);
    table.row(&[
        "price median / P90".into(),
        "≈ 0.60".into(),
        format!("{:.3} ± {:.3}", stats::mean(&ratios), stats::std_dev(&ratios)),
    ]);
    table.row(&[
        "availability cap".into(),
        "16 (regional)".into(),
        "16".into(),
    ]);
    table.row(&[
        "mean availability".into(),
        "fluctuating, often scarce".into(),
        format!("{:.1}", stats::mean(&avail_means)),
    ]);
    table.row(&[
        "zero-availability slots".into(),
        "present".into(),
        format!("{:.1}%", 100.0 * stats::mean(&starved)),
    ]);
    table.row(&[
        "diurnal cycle".into(),
        "day > night".into(),
        "reproduced (below)".into(),
    ]);
    table.row(&[
        "avail autocorr (lag 1)".into(),
        "high (predictable)".into(),
        f(stats::mean(&ac_avail), 2),
    ]);
    table.print();

    // Reference trace: one seed's full series + diurnal profile to CSV.
    let t = gen.generate(7);
    let mut csv =
        CsvWriter::create("results/fig2_trace.csv", &["slot", "price", "avail"])
            .expect("csv");
    for i in 0..t.len() {
        csv.row_f64(&[i as f64, t.price_at(i), t.avail_at(i) as f64]);
    }
    csv.finish().expect("csv");

    let prof = diurnal_profile(&t, 48);
    let mut csv2 = CsvWriter::create(
        "results/fig2_diurnal.csv",
        &["slot_of_day", "mean_avail"],
    )
    .expect("csv");
    for (i, v) in prof.iter().enumerate() {
        csv2.row_f64(&[i as f64, *v]);
    }
    csv2.finish().expect("csv");

    let day = stats::mean(&prof[18..36].to_vec());
    let night: Vec<f64> = prof[..12].iter().chain(&prof[42..]).cloned().collect();
    println!(
        "\ndiurnal: day {:.1} vs night {:.1} instances available",
        day,
        stats::mean(&night)
    );
    println!("wrote results/fig2_trace.csv, results/fig2_diurnal.csv");
}
