//! Fig. 1 — training throughput vs number of GPUs (paper: ChatGLM3-6B &
//! Llama2-7B on A100s, batch 32, near-linear scaling).
//!
//! Substitution (DESIGN.md): our "instances" are data-parallel shards
//! executed by one PJRT CPU client, so wall-clock is sequential. The
//! empirical basis for the paper's `H(n) = αn + β` is therefore measured
//! as (a) per-shard grad-step time staying flat as n grows (no
//! coordination overhead ⇒ parallel aggregate is linear) and (b) the
//! modeled aggregate `n · B · steps/slot`. The fitted α/β and linearity
//! R² are printed — the quantity the scheduler actually consumes.

use std::path::PathBuf;

use spotfine::runtime::artifact::ArtifactBundle;
use spotfine::runtime::client::RuntimeClient;
use spotfine::runtime::executable::TrainStepExec;
use spotfine::train::trainer::{Trainer, TrainerConfig};
use spotfine::util::csvio::CsvWriter;
use spotfine::util::stats;
use spotfine::util::table::{f, Table};

fn main() {
    println!("=== Fig. 1: throughput vs #instances ===");
    let dir = PathBuf::from("artifacts");
    if !ArtifactBundle::present(&dir) {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let client = RuntimeClient::cpu().expect("pjrt client");
    let bundle = ArtifactBundle::load(&dir).expect("bundle");
    let batch = bundle.meta.batch_per_shard;
    let preset = bundle.meta.preset.clone();
    let exec = TrainStepExec::compile(&client, bundle).expect("compile");
    let mut trainer = Trainer::new(exec, TrainerConfig::default()).expect("trainer");

    let shard_counts = [1usize, 2, 3, 4, 6, 8];
    let steps = 3;
    let mut table = Table::new(&[
        "instances n",
        "modeled samples/slot",
        "per-shard step ms",
        "wall samples/s",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig1_throughput.csv",
        &["n", "modeled_samples_per_slot", "per_shard_ms", "wall_sps"],
    )
    .expect("csv");
    let mut ns = Vec::new();
    let mut modeled = Vec::new();
    let mut per_shard = Vec::new();
    for &n in &shard_counts {
        // per-count warmup (allocator + cache shape differ per n)
        trainer.step_parallel(n).expect("warmup");
        let t0 = std::time::Instant::now();
        let mut samples = 0usize;
        for _ in 0..steps {
            samples += trainer.step_parallel(n).expect("step").samples;
        }
        let dt = t0.elapsed().as_secs_f64();
        let wall_sps = samples as f64 / dt;
        let shard_ms = dt * 1e3 / (steps * n) as f64;
        let model_sps = (n * batch * steps) as f64; // per slot-equivalent
        table.row(&[
            n.to_string(),
            f(model_sps, 0),
            f(shard_ms, 1),
            f(wall_sps, 1),
        ]);
        csv.row_f64(&[n as f64, model_sps, shard_ms, wall_sps]);
        ns.push(n as f64);
        modeled.push(model_sps);
        per_shard.push(shard_ms);
    }
    table.print();
    csv.finish().expect("csv write");

    // Linearity: modeled aggregate is exactly linear by construction IF
    // per-shard time is flat; report the per-shard flatness.
    let (slope, intercept) = stats::linfit(&ns, &per_shard);
    let drift = slope * (ns[ns.len() - 1] - ns[0]) / stats::mean(&per_shard);
    let (alpha, beta) = stats::linfit(&ns, &modeled);
    println!("\npreset `{preset}`: fitted H(n) = {alpha:.1}·n + {beta:.1} samples/slot");
    println!(
        "per-shard step time {:.1} ms, drift {:+.1}% across 1→8 shards.",
        intercept,
        100.0 * drift
    );
    println!(
        "On this 1-core box all shards share one cache, so per-shard time \
         rises with n (gradient buffers ≫ L2); on the paper's testbed each \
         GPU has private memory and the aggregate is the modeled linear \
         H(n) — the quantity the scheduler consumes (Eq. 1)."
    );
    assert!(
        drift.abs() < 1.0,
        "per-shard cost should stay within 2× across the sweep (got {:+.0}%)",
        100.0 * drift
    );
    println!("wrote results/fig1_throughput.csv");
}
