//! Fig. 7 — impact of average spot-instance availability. Paper shape:
//! AHAP/AHANP stay among the top performers across all settings; scarce
//! availability compresses everyone toward OD-Only (nothing to exploit),
//! abundant availability lifts spot-capable policies.

#[path = "sweep_common.rs"]
mod sweep_common;

use spotfine::forecast::noise::NoiseSpec;
use spotfine::market::generator::GeneratorConfig;
use spotfine::sched::job::JobGenerator;
use spotfine::sched::policy::Models;
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};
use sweep_common::evaluate_point;

fn main() {
    println!("=== Fig. 7: utility vs average spot availability ===");
    let scales = [0.4f64, 0.7, 1.0, 1.3, 1.6];
    let n_jobs = 120;
    let noise = NoiseSpec::fixed_mag_uniform(0.1);
    let jobs = JobGenerator::default();
    let models = Models::paper_default();

    let mut table = Table::new(&[
        "avail scale", "OD-Only", "MSU", "UP", "AHANP", "AHAP",
    ]);
    let mut csv = CsvWriter::create(
        "results/fig7_availability.csv",
        &["avail_scale", "group", "utility", "misses"],
    )
    .expect("csv");
    let mut ahap_series = Vec::new();
    let mut best_other = Vec::new();
    for &scale in &scales {
        let gen_cfg = GeneratorConfig { avail_scale: scale, ..GeneratorConfig::default() };
        let scores = evaluate_point(&gen_cfg, &jobs, &models, noise, n_jobs, 42);
        let get = |n: &str| scores.iter().find(|s| s.name == n).unwrap();
        table.row(&[
            f(scale, 1),
            f(get("OD-Only").utility, 1),
            f(get("MSU").utility, 1),
            f(get("UP").utility, 1),
            f(get("AHANP").utility, 1),
            f(get("AHAP").utility, 1),
        ]);
        for s in &scores {
            csv.row(&[
                format!("{scale:.1}"),
                s.name.to_string(),
                format!("{:.4}", s.utility),
                s.misses.to_string(),
            ]);
        }
        ahap_series.push(get("AHAP").utility);
        best_other.push(
            ["OD-Only", "MSU", "UP"]
                .iter()
                .map(|n| get(n).utility)
                .fold(f64::NEG_INFINITY, f64::max),
        );
    }
    table.print();
    csv.finish().expect("csv");

    // Shape: AHAP ≥ the best non-adaptive baseline at every point, and
    // utility grows with availability.
    for (i, (&a, &b)) in ahap_series.iter().zip(&best_other).enumerate() {
        assert!(
            a >= b - 1e-9,
            "shape violated at scale {}: AHAP {a} < best baseline {b}",
            scales[i]
        );
    }
    assert!(
        ahap_series.last().unwrap() > ahap_series.first().unwrap(),
        "more availability must help"
    );
    println!("\nshape OK: AHAP top-performing at every availability level; wrote results/fig7_availability.csv");
}
