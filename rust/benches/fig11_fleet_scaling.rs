//! Fig. 11 (ours) — fleet scaling: wall-clock of the multi-job,
//! multi-region fleet engine as the fleet grows (jobs × regions), and
//! the speedup of the `std::thread::scope` parallel sweep engine over
//! sequential execution at 1/2/4/8 threads on a 64-job fleet.
//!
//! Acceptance target: >2× sweep speedup at ≥4 threads on a 64-job
//! fleet (on a host with ≥4 cores). Parallel results are also checked
//! identical to sequential — the sweep is deterministic by design.

use spotfine::fleet::{available_threads, run_fleet_sweep, FleetScenario};
use spotfine::util::bench::{section, time_once};
use spotfine::util::csvio::CsvWriter;
use spotfine::util::table::{f, Table};

fn main() {
    println!("=== Fig. 11: fleet scaling (jobs x regions x threads) ===");
    println!("host parallelism: {} threads\n", available_threads());

    let mut csv = CsvWriter::create(
        "results/fig11_fleet_scaling.csv",
        &["section", "jobs", "regions", "threads", "seconds", "speedup"],
    )
    .expect("csv");

    // --- Engine scaling: one fleet, growing jobs × regions. -----------
    section("engine scaling (single fleet, sequential)");
    let mut t = Table::new(&[
        "jobs",
        "regions",
        "seconds",
        "job-slots/s",
        "mean utility",
        "on-time",
    ]);
    for &jobs in &[8usize, 16, 32, 64] {
        for &regions in &[1usize, 2, 4] {
            let sc = FleetScenario::new(jobs, regions, 42).with_stagger(2);
            let (r, secs) = time_once(|| sc.run());
            let job_slots: usize =
                r.jobs.iter().map(|j| j.episode.decisions.len()).sum();
            t.row(&[
                format!("{jobs}"),
                format!("{regions}"),
                format!("{secs:.3}"),
                format!("{:.0}", job_slots as f64 / secs.max(1e-9)),
                f(r.mean_utility(), 2),
                format!("{:.0}%", 100.0 * r.on_time_rate),
            ]);
            csv.row(&[
                "engine".into(),
                format!("{jobs}"),
                format!("{regions}"),
                "1".into(),
                format!("{secs:.6}"),
                "1.0".into(),
            ]);
        }
    }
    t.print();

    // --- Parallel sweep: 64-job fleets fanned across threads. ---------
    section("parallel sweep speedup (64-job, 4-region fleets x 16 seeds)");
    let scenarios: Vec<FleetScenario> = (0..16)
        .map(|s| FleetScenario::new(64, 4, 1000 + s).with_stagger(2))
        .collect();

    let (baseline, base_secs) = time_once(|| run_fleet_sweep(&scenarios, 1));
    let mut t = Table::new(&["threads", "seconds", "speedup", "identical"]);
    t.row(&[
        "1".into(),
        format!("{base_secs:.3}"),
        "1.00x".into(),
        "-".into(),
    ]);
    csv.row(&[
        "sweep".into(),
        "64".into(),
        "4".into(),
        "1".into(),
        format!("{base_secs:.6}"),
        "1.0".into(),
    ]);
    for &threads in &[2usize, 4, 8] {
        let (r, secs) = time_once(|| run_fleet_sweep(&scenarios, threads));
        let speedup = base_secs / secs.max(1e-9);
        let identical = r == baseline;
        assert!(
            identical,
            "parallel sweep at {threads} threads diverged from sequential"
        );
        t.row(&[
            format!("{threads}"),
            format!("{secs:.3}"),
            format!("{speedup:.2}x"),
            "yes".into(),
        ]);
        csv.row(&[
            "sweep".into(),
            "64".into(),
            "4".into(),
            format!("{threads}"),
            format!("{secs:.6}"),
            format!("{speedup:.3}"),
        ]);
        if threads >= 4 && available_threads() >= 4 {
            println!(
                "  -> {threads}-thread speedup {speedup:.2}x \
                 (target >2x on a 64-job fleet)"
            );
        }
    }
    t.print();

    let path = csv.finish().expect("write csv");
    println!("\nwrote {}", path.display());
}
