"""AOT path: lowering produces parseable HLO text with the expected
entry signature, and meta.toml matches the calling convention."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile.aot import (
    lower_apply_step,
    lower_grad_step,
    lower_init,
    to_hlo_text,
    write_meta,
)
from compile.model import ModelConfig, OptConfig, param_specs

CFG = ModelConfig(
    vocab=31, d_model=16, n_layers=1, n_heads=2, d_ff=32, seq_len=8,
    lora_rank=2, batch_per_shard=2,
)


@pytest.fixture(scope="module")
def grad_hlo():
    return to_hlo_text(lower_grad_step(CFG))


class TestLowering:
    def test_grad_step_hlo_structure(self, grad_hlo):
        assert grad_hlo.startswith("HloModule")
        assert "ENTRY" in grad_hlo
        # one parameter per frozen + trainable tensor + tokens
        f, t = param_specs(CFG)
        nparams = len(f) + len(t) + 1
        assert grad_hlo.count("parameter(") >= nparams

    def test_grad_step_io_shapes(self, grad_hlo):
        # tokens input present as s32[B, S+1]
        assert f"s32[{CFG.batch_per_shard},{CFG.seq_len + 1}]" in grad_hlo
        # entry returns a tuple starting with the scalar loss
        assert "->" in grad_hlo

    def test_apply_step_lowers(self):
        hlo = to_hlo_text(lower_apply_step(CFG, OptConfig()))
        assert hlo.startswith("HloModule")
        _, t = param_specs(CFG)
        # 4 tensor groups + step scalar
        assert hlo.count("parameter(") >= 4 * len(t) + 1

    def test_init_lowers_without_inputs(self):
        hlo = to_hlo_text(lower_init(CFG, seed=3))
        assert hlo.startswith("HloModule")

    def test_no_mosaic_custom_calls(self, grad_hlo):
        # interpret=True must fully inline the Pallas kernels; a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        assert "mosaic" not in grad_hlo.lower()


class TestMeta:
    def test_meta_roundtrips(self, tmp_path):
        path = tmp_path / "meta.toml"
        write_meta(str(path), "test", CFG, OptConfig(), seed=0)
        text = path.read_text()
        assert "[model]" in text
        assert f"vocab = {CFG.vocab}" in text
        f_specs, t_specs = param_specs(CFG)
        # every parameter name listed exactly once
        for name, _ in f_specs + t_specs:
            assert text.count(f'"{name}"') == 1

    def test_meta_is_minimal_toml(self, tmp_path):
        # must not use syntax rust's mini-parser rejects (inline tables,
        # dotted keys outside headers, multiline strings)
        path = tmp_path / "meta.toml"
        write_meta(str(path), "test", CFG, OptConfig(), seed=0)
        for line in path.read_text().splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            assert line.startswith("[") or "=" in line
            assert "'''" not in line and '"""' not in line


class TestCliDriver:
    def test_aot_main_writes_all_artifacts(self, tmp_path):
        out = tmp_path / "model.hlo.txt"
        env = dict(os.environ)
        env["SPOTFINE_PRESET"] = "tiny"
        # run the real CLI as `make artifacts` does, but into tmp
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out)],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            env=env,
            timeout=600,
        )
        for f in ["grad_step.hlo.txt", "apply_step.hlo.txt",
                  "init.hlo.txt", "meta.toml", "model.hlo.txt"]:
            assert (tmp_path / f).exists(), f
            assert (tmp_path / f).stat().st_size > 0
