"""L1 correctness: Pallas kernels vs the pure-jnp oracles in
``kernels/ref.py`` — the CORE correctness signal of the compile path.

Fixed cases pin down exact expectations; hypothesis sweeps shapes,
dtypes, scales, and block sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.lora_matmul import (
    _pick_block,
    lora_matmul,
    vmem_bytes_estimate,
)
from compile.kernels.ref import lora_matmul_ref, softmax_xent_ref
from compile.kernels.softmax_xent import softmax_xent


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(
        dtype
    )


class TestLoraMatmul:
    def test_matches_ref_basic(self):
        x = rand(0, (32, 64))
        w0 = rand(1, (64, 96))
        a = rand(2, (64, 8), scale=0.1)
        b = rand(3, (8, 96), scale=0.1)
        y = lora_matmul(x, w0, a, b, 2.0)
        yr = lora_matmul_ref(x, w0, a, b, 2.0)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)

    def test_zero_adapter_is_base_matmul(self):
        # LoRA init (B = 0): output must equal the frozen base projection.
        x = rand(0, (16, 32))
        w0 = rand(1, (32, 32))
        a = rand(2, (32, 4))
        b = jnp.zeros((4, 32))
        y = lora_matmul(x, w0, a, b, 2.0)
        np.testing.assert_allclose(y, x @ w0, rtol=1e-5, atol=1e-5)

    def test_scale_zero_kills_adapter(self):
        x = rand(0, (16, 32))
        w0 = rand(1, (32, 32))
        a = rand(2, (32, 4))
        b = rand(3, (4, 32))
        y = lora_matmul(x, w0, a, b, 0.0)
        np.testing.assert_allclose(y, x @ w0, rtol=1e-5, atol=1e-5)

    def test_grid_tiling_matches_single_block(self):
        # Force a multi-step grid and compare against one big block.
        x = rand(0, (64, 32))
        w0 = rand(1, (32, 64))
        a = rand(2, (32, 8), scale=0.2)
        b = rand(3, (8, 64), scale=0.2)
        y_tiled = lora_matmul(x, w0, a, b, 1.5, block_m=16, block_n=16)
        y_one = lora_matmul(x, w0, a, b, 1.5, block_m=64, block_n=64)
        np.testing.assert_allclose(y_tiled, y_one, rtol=1e-5, atol=1e-5)

    def test_bfloat16_accumulates_in_f32(self):
        x = rand(0, (32, 64), jnp.bfloat16)
        w0 = rand(1, (64, 64), jnp.bfloat16)
        a = rand(2, (64, 8), jnp.bfloat16, scale=0.1)
        b = rand(3, (8, 64), jnp.bfloat16, scale=0.1)
        y = lora_matmul(x, w0, a, b, 2.0)
        yr = lora_matmul_ref(x, w0, a, b, 2.0)
        assert y.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            y.astype(np.float32), yr.astype(np.float32), rtol=5e-2, atol=5e-2
        )

    def test_shape_mismatch_raises(self):
        x = rand(0, (8, 16))
        w0 = rand(1, (17, 8))
        a = rand(2, (16, 4))
        b = rand(3, (4, 8))
        with pytest.raises(ValueError):
            lora_matmul(x, w0, a, b, 1.0)

    def test_gradients_match_ref(self):
        x = rand(0, (16, 32))
        w0 = rand(1, (32, 24))
        a = rand(2, (32, 4), scale=0.3)
        b = rand(3, (4, 24), scale=0.3)

        def f_kernel(x, a, b):
            return jnp.sum(jnp.sin(lora_matmul(x, w0, a, b, 2.0)))

        def f_ref(x, a, b):
            return jnp.sum(jnp.sin(lora_matmul_ref(x, w0, a, b, 2.0)))

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, a, b)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, a, b)
        for k, r in zip(gk, gr):
            np.testing.assert_allclose(k, r, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 24, 48, 64]),
        k=st.sampled_from([16, 32, 64]),
        n=st.sampled_from([16, 32, 96]),
        r=st.sampled_from([1, 4, 8, 16]),
        scale=st.floats(0.0, 4.0),
        block=st.sampled_from([8, 16, 128]),
    )
    def test_property_matches_ref(self, m, k, n, r, scale, block):
        x = rand(m * 7 + k, (m, k))
        w0 = rand(k * 5 + n, (k, n))
        a = rand(r + 11, (k, r), scale=0.2)
        b = rand(r + 13, (r, n), scale=0.2)
        y = lora_matmul(x, w0, a, b, scale, block_m=block, block_n=block)
        yr = lora_matmul_ref(x, w0, a, b, scale)
        np.testing.assert_allclose(y, yr, rtol=2e-5, atol=2e-5)

    def test_pick_block_divides(self):
        for dim in [1, 7, 16, 48, 100, 128, 129]:
            for pref in [1, 8, 128]:
                b = _pick_block(dim, pref)
                assert dim % b == 0
                assert 1 <= b <= max(pref, 1)

    def test_vmem_estimate_sane(self):
        # tiny preset attention projection: within a few MiB.
        est = vmem_bytes_estimate(m=512, k=128, n=128, r=8)
        assert 0 < est < 16 * 2**20


class TestSoftmaxXent:
    def test_matches_ref(self):
        logits = rand(0, (128, 50), scale=3.0)
        targets = jax.random.randint(jax.random.PRNGKey(1), (128,), 0, 50)
        l1 = softmax_xent(logits, targets)
        l2 = softmax_xent_ref(logits, targets)
        np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-6)

    def test_perfect_prediction_low_loss(self):
        n, v = 64, 10
        targets = jnp.arange(n) % v
        logits = jax.nn.one_hot(targets, v) * 50.0
        loss = softmax_xent(logits, targets)
        assert float(loss) < 1e-3

    def test_uniform_logits_log_v(self):
        n, v = 32, 17
        logits = jnp.zeros((n, v))
        targets = jnp.zeros((n,), jnp.int32)
        loss = softmax_xent(logits, targets)
        np.testing.assert_allclose(float(loss), np.log(v), rtol=1e-6)

    def test_large_logits_stable(self):
        logits = rand(0, (32, 16), scale=1e4)
        targets = jax.random.randint(jax.random.PRNGKey(2), (32,), 0, 16)
        loss = softmax_xent(logits, targets)
        assert np.isfinite(float(loss))

    def test_gradient_matches_ref(self):
        logits = rand(0, (64, 20), scale=2.0)
        targets = jax.random.randint(jax.random.PRNGKey(3), (64,), 0, 20)
        gk = jax.grad(lambda l: softmax_xent(l, targets))(logits)
        gr = jax.grad(lambda l: softmax_xent_ref(l, targets))(logits)
        np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-6)
        # gradient rows sum to ~0 (softmax minus onehot property)
        np.testing.assert_allclose(gk.sum(-1), np.zeros(64), atol=1e-7)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            softmax_xent(jnp.zeros((8, 4)), jnp.zeros((7,), jnp.int32))

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([8, 32, 96, 256]),
        v=st.sampled_from([2, 11, 64]),
        block=st.sampled_from([4, 8, 256]),
        scale=st.floats(0.1, 10.0),
    )
    def test_property_matches_ref(self, n, v, block, scale):
        logits = rand(n + v, (n, v), scale=scale)
        targets = jax.random.randint(
            jax.random.PRNGKey(n * 3 + v), (n,), 0, v
        )
        l1 = softmax_xent(logits, targets, block_rows=block)
        l2 = softmax_xent_ref(logits, targets)
        np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)
