"""L2 correctness: model shapes, LoRA semantics, gradients, and the
AdamW apply step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    OptConfig,
    PRESETS,
    apply_step,
    forward,
    grad_step,
    init_params,
    loss_fn,
    make_example_tokens,
    param_specs,
)

CFG = ModelConfig(
    vocab=61, d_model=32, n_layers=2, n_heads=4, d_ff=48, seq_len=16,
    lora_rank=4, batch_per_shard=2,
)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


def tokens(seed=0, cfg=CFG):
    return jax.random.randint(
        jax.random.PRNGKey(seed),
        (cfg.batch_per_shard, cfg.seq_len + 1),
        0,
        cfg.vocab,
    )


class TestSpecs:
    def test_spec_counts(self):
        f, t = param_specs(CFG)
        assert len(f) == 8 * CFG.n_layers
        assert len(t) == 1 + 6 * CFG.n_layers + 2

    def test_init_matches_specs(self, params):
        frozen, trainable = params
        f_specs, t_specs = param_specs(CFG)
        assert len(frozen) == len(f_specs)
        assert len(trainable) == len(t_specs)
        for arr, (_, shape) in zip(frozen, f_specs):
            assert arr.shape == shape
        for arr, (_, shape) in zip(trainable, t_specs):
            assert arr.shape == shape

    def test_lora_b_zero_init(self, params):
        _, trainable = params
        _, t_specs = param_specs(CFG)
        for arr, (name, _) in zip(trainable, t_specs):
            if name.endswith("_b"):
                assert float(jnp.abs(arr).max()) == 0.0

    def test_param_count_consistent(self):
        f, t = param_specs(CFG)
        total = sum(int(np.prod(s)) for _, s in f + t)
        assert CFG.param_count() == total

    def test_presets_exist(self):
        assert set(PRESETS) == {"tiny", "small", "100m"}
        # the 100m preset should be ~O(100M) params
        assert PRESETS["100m"].param_count() > 50_000_000


class TestForward:
    def test_logits_shape(self, params):
        frozen, trainable = params
        logits = forward(CFG, frozen, trainable, tokens())
        assert logits.shape == (CFG.batch_per_shard, CFG.seq_len, CFG.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))

    def test_causality(self, params):
        # Changing a future token must not affect earlier logits.
        frozen, trainable = params
        tk = tokens(1)
        logits1 = forward(CFG, frozen, trainable, tk)
        tk2 = tk.at[:, -2].set((tk[:, -2] + 1) % CFG.vocab)
        logits2 = forward(CFG, frozen, trainable, tk2)
        np.testing.assert_allclose(
            logits1[:, : CFG.seq_len - 2], logits2[:, : CFG.seq_len - 2],
            rtol=1e-5, atol=1e-5,
        )

    def test_loss_positive_and_near_log_v(self, params):
        frozen, trainable = params
        loss = loss_fn(CFG, frozen, trainable, tokens(2))
        # untrained model ≈ uniform predictions
        assert 0.5 * np.log(CFG.vocab) < float(loss) < 2.0 * np.log(CFG.vocab)


class TestGradStep:
    def test_outputs_shapes(self, params):
        frozen, trainable = params
        out = grad_step(CFG, frozen, trainable, tokens(3))
        assert len(out) == 1 + len(trainable)
        assert out[0].shape == ()
        for g, p in zip(out[1:], trainable):
            assert g.shape == p.shape

    def test_grads_nonzero_and_finite(self, params):
        frozen, trainable = params
        out = grad_step(CFG, frozen, trainable, tokens(4))
        total = 0.0
        for g in out[1:]:
            arr = np.asarray(g)
            assert np.all(np.isfinite(arr))
            total += float(np.abs(arr).sum())
        assert total > 0.0

    def test_lora_a_grads_zero_at_init(self, params):
        # With B = 0 the loss is locally independent of A (dL/dA = s·xᵀ
        # (dy·Bᵀ) = 0) — a sharp regression test of the custom VJP.
        frozen, trainable = params
        out = grad_step(CFG, frozen, trainable, tokens(5))
        _, t_specs = param_specs(CFG)
        for g, (name, _) in zip(out[1:], t_specs):
            if name.endswith("_a"):
                np.testing.assert_allclose(
                    np.asarray(g), 0.0, atol=1e-7,
                    err_msg=f"A-grad for {name} should vanish at B=0",
                )

    def test_sgd_descent_direction(self, params):
        # One small step along -grad must reduce the loss.
        frozen, trainable = params
        tk = tokens(6)
        out = grad_step(CFG, frozen, trainable, tk)
        loss0 = float(out[0])
        stepped = tuple(
            p - 0.05 * g for p, g in zip(trainable, out[1:])
        )
        loss1 = float(loss_fn(CFG, frozen, stepped, tk))
        assert loss1 < loss0, f"{loss1} !< {loss0}"


class TestApplyStep:
    def test_adamw_moves_params(self, params):
        _, trainable = params
        opt = OptConfig()
        zeros = tuple(jnp.zeros_like(p) for p in trainable)
        grads = tuple(jnp.ones_like(p) * 0.1 for p in trainable)
        out = apply_step(opt, trainable, zeros, zeros, grads,
                         jnp.asarray(1, jnp.int32))
        k = len(trainable)
        new_t, new_m, new_v = out[:k], out[k : 2 * k], out[2 * k :]
        for p0, p1 in zip(trainable, new_t):
            assert float(jnp.abs(p1 - p0).max()) > 0.0
        for m in new_m:
            assert float(jnp.abs(m).max()) > 0.0
        for v in new_v:
            assert float(v.min()) >= 0.0

    def test_zero_grad_only_decays(self, params):
        _, trainable = params
        opt = OptConfig(weight_decay=0.1)
        zeros = tuple(jnp.zeros_like(p) for p in trainable)
        out = apply_step(opt, trainable, zeros, zeros, zeros,
                         jnp.asarray(1, jnp.int32))
        new_t = out[: len(trainable)]
        for p0, p1 in zip(trainable, new_t):
            # pure weight decay: p1 = p0(1 − lr·wd)
            np.testing.assert_allclose(
                np.asarray(p1), np.asarray(p0) * (1 - opt.lr * 0.1),
                rtol=1e-5, atol=1e-8,
            )

    def test_training_loop_reduces_loss(self, params):
        # 12 jitted AdamW steps on a repeating batch — the end-to-end L2
        # sanity check that the whole (kernel → model → optimizer) stack
        # actually learns.
        frozen, trainable = params
        opt = OptConfig(lr=3e-3)
        m = tuple(jnp.zeros_like(p) for p in trainable)
        v = tuple(jnp.zeros_like(p) for p in trainable)
        tk = tokens(7)
        gstep = jax.jit(lambda tr, t: grad_step(CFG, frozen, tr, t))
        astep = jax.jit(
            lambda tr, m, v, g, s: apply_step(opt, tr, m, v, g, s)
        )
        losses = []
        tr = trainable
        k = len(trainable)
        for step in range(12):
            out = gstep(tr, tk)
            losses.append(float(out[0]))
            upd = astep(tr, m, v, out[1:], jnp.asarray(step + 1, jnp.int32))
            tr, m, v = upd[:k], upd[k : 2 * k], upd[2 * k :]
        assert losses[-1] < losses[0] - 0.1, f"losses: {losses}"


class TestExampleTokens:
    def test_shape_dtype(self):
        tk = make_example_tokens(CFG)
        assert tk.shape == (CFG.batch_per_shard, CFG.seq_len + 1)
        assert tk.dtype == jnp.int32
