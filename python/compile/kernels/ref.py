"""Pure-jnp oracles for the Pallas kernels.

These are the correctness signal: every kernel in this package has a
reference implementation here, and ``python/tests/test_kernel.py`` sweeps
shapes/dtypes (hypothesis) asserting allclose between kernel and oracle.
"""

import jax.numpy as jnp


def lora_matmul_ref(x, w0, a, b, scale):
    """Fused LoRA projection: ``y = x @ W0 + scale * (x @ A) @ B``.

    Args:
      x:     [m, k]  activations.
      w0:    [k, n]  frozen base weight.
      a:     [k, r]  LoRA down-projection (trainable).
      b:     [r, n]  LoRA up-projection (trainable).
      scale: python float — LoRA scaling (alpha / rank).

    Returns:
      [m, n] output in ``x.dtype``, accumulated in float32.
    """
    xf = x.astype(jnp.float32)
    acc = jnp.dot(xf, w0.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    low = jnp.dot(xf, a.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc = acc + scale * jnp.dot(low, b.astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def softmax_xent_ref(logits, targets):
    """Mean softmax cross-entropy over all rows.

    Args:
      logits:  [n, v] float logits.
      targets: [n]    integer class ids.

    Returns:
      scalar float32 mean loss.
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[:, 0]
    picked = jnp.take_along_axis(
        logits, targets[:, None].astype(jnp.int32), axis=-1
    )[:, 0]
    return jnp.mean(lse - picked)
