"""L1 Pallas kernel: row-blocked softmax cross-entropy.

The LM loss over ``[batch·seq, vocab]`` logits is the second-largest
memory mover in the train step (the logits tensor dwarfs activations).
The kernel walks row blocks, keeping each ``(block_rows × vocab)`` tile
in VMEM, computes the numerically-stable log-sum-exp in one pass, and
emits per-block summed losses; the (tiny) final reduction happens in the
surrounding jnp graph.

TPU adaptation notes: a CUDA implementation would warp-reduce per row;
on TPU the whole row block reduces on the VPU with lane-wide ``max`` /
``sum`` — the BlockSpec is the schedule, no explicit shuffles.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _pick_block(dim, preferred):
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _xent_kernel(logits_ref, targets_ref, o_ref):
    logits = logits_ref[...].astype(jnp.float32)  # [bm, v]
    targets = targets_ref[...]  # [bm]
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[:, 0]
    picked = jnp.take_along_axis(
        logits, targets[:, None].astype(jnp.int32), axis=-1
    )[:, 0]
    o_ref[...] = jnp.sum(lse - picked, keepdims=True)


def _softmax_xent_call(logits, targets, block_rows, interpret):
    n, v = logits.shape
    if targets.shape != (n,):
        raise ValueError(f"targets {targets.shape} != ({n},)")
    bm = _pick_block(n, block_rows)
    grid = (n // bm,)
    partial_sums = pl.pallas_call(
        _xent_kernel,
        out_shape=jax.ShapeDtypeStruct((n // bm,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, v), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=interpret,
    )(logits, targets.astype(jnp.int32))
    return jnp.sum(partial_sums) / n


# Custom VJP (pallas_call has no reverse-mode rule): the classical
# d logits = (softmax − onehot) / n. Integer targets get a float0
# cotangent per JAX convention.
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _softmax_xent_diff(logits, targets, block_rows, interpret):
    return _softmax_xent_call(logits, targets, block_rows, interpret)


def _xent_fwd(logits, targets, block_rows, interpret):
    loss = _softmax_xent_call(logits, targets, block_rows, interpret)
    return loss, (logits, targets)


def _xent_bwd(block_rows, interpret, res, dloss):
    logits, targets = res
    n, v = logits.shape
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(targets, v, dtype=jnp.float32)
    dlogits = (p - onehot) * (dloss / n)
    return (
        dlogits.astype(logits.dtype),
        np.zeros(targets.shape, dtype=jax.dtypes.float0),
    )


_softmax_xent_diff.defvjp(_xent_fwd, _xent_bwd)


def softmax_xent(logits, targets, *, block_rows=256, interpret=True):
    """Mean softmax cross-entropy via a row-blocked Pallas kernel
    (differentiable in ``logits``).

    Args:
      logits:  [n, v] float logits.
      targets: [n] int32 class ids.
      block_rows: preferred rows per grid step (clipped to a divisor).
      interpret: run in interpret mode (required on CPU).

    Returns:
      scalar float32 mean loss.
    """
    return _softmax_xent_diff(logits, targets, block_rows, interpret)
