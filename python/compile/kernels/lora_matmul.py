"""L1 Pallas kernel: the fused LoRA projection — the paper's fine-tuning
compute hot-spot (§II-A) — re-thought for TPU execution.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA recipe for
LoRA (threadblock tiles of ``x``/``W0`` in shared memory, WMMA tensor-core
fragments, adapter cached in shared memory) maps onto TPU as:

- ``BlockSpec`` tiles stage HBM→VMEM; the grid walks MXU-shaped
  ``(block_m, block_n)`` output tiles;
- the low-rank factors ``A (k×r)`` and ``B (r×n-block)`` are tiny
  (r ≤ 32), so **A rides along every grid step** (index_map pinned to
  (0,0)) and stays VMEM-resident — the TPU analogue of caching the
  adapter in shared memory;
- both matmuls accumulate in float32 via ``preferred_element_type`` —
  the MXU's native accumulation — so bf16 inputs don't lose the LoRA
  correction (which is orders of magnitude smaller than the base term).

``interpret=True`` is mandatory on this CPU-PJRT image: real TPU lowering
emits a Mosaic custom-call the CPU plugin cannot execute. The kernel
structure (tiling, residency, accumulation) is what carries to real TPUs;
DESIGN.md/EXPERIMENTS.md §Perf hold the VMEM/MXU estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, preferred):
    """Largest divisor of ``dim`` that is ≤ preferred (MXU-aligned when
    the dimension allows it)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return b


def _lora_kernel(x_ref, w0_ref, a_ref, b_ref, o_ref, *, scale):
    x = x_ref[...].astype(jnp.float32)
    # Base projection: the (block_m × k) · (k × block_n) MXU matmul.
    acc = jnp.dot(x, w0_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    # Low-rank correction: two skinny matmuls against the VMEM-resident
    # adapter; r ≤ 32 keeps these on the MXU's shortcut path.
    low = jnp.dot(x, a_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc = acc + scale * jnp.dot(low, b_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _lora_matmul_call(x, w0, a, b, scale, block_m, block_n, interpret):
    m, k = x.shape
    k2, n = w0.shape
    k3, r = a.shape
    r2, n2 = b.shape
    if k != k2 or k != k3 or r != r2 or n != n2:
        raise ValueError(
            f"shape mismatch: x{x.shape} w0{w0.shape} a{a.shape} b{b.shape}"
        )
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    kernel = functools.partial(_lora_kernel, scale=float(scale))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            # x: stream row-tiles; full k (k fits VMEM at our widths).
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # w0: stream column-tiles.
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            # a: VMEM-resident across the whole grid.
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            # b: column-tile of the up-projection.
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x, w0, a, b)


# pallas_call has no built-in reverse-mode rule, so the kernel carries a
# custom VJP. The backward pass is plain jnp (two skinny matmuls + one
# dense one) — it lowers into the same HLO module; the Pallas tiling is
# the *forward* hot-spot. The frozen W0 still receives a (DCE-able) zero
# cotangent because custom_vjp must produce one per primal.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _lora_matmul_diff(x, w0, a, b, scale, block_m, block_n, interpret):
    return _lora_matmul_call(x, w0, a, b, scale, block_m, block_n, interpret)


def _lora_fwd(x, w0, a, b, scale, block_m, block_n, interpret):
    y = _lora_matmul_call(x, w0, a, b, scale, block_m, block_n, interpret)
    return y, (x, w0, a, b)


def _lora_bwd(scale, block_m, block_n, interpret, res, dy):
    x, w0, a, b = res
    dyf = dy.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    # dx = dy·W0ᵀ + s·(dy·Bᵀ)·Aᵀ
    dy_bt = jnp.dot(dyf, b.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)
    dx = jnp.dot(dyf, w0.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)
    dx = dx + scale * jnp.dot(dy_bt, a.astype(jnp.float32).T,
                              preferred_element_type=jnp.float32)
    # da = s·xᵀ·(dy·Bᵀ);  db = s·(x·A)ᵀ·dy
    da = scale * jnp.dot(xf.T, dy_bt, preferred_element_type=jnp.float32)
    u = jnp.dot(xf, a.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    db = scale * jnp.dot(u.T, dyf, preferred_element_type=jnp.float32)
    return (
        dx.astype(x.dtype),
        jnp.zeros_like(w0),
        da.astype(a.dtype),
        db.astype(b.dtype),
    )


_lora_matmul_diff.defvjp(_lora_fwd, _lora_bwd)


def lora_matmul(x, w0, a, b, scale, *, block_m=128, block_n=128,
                interpret=True):
    """Fused ``y = x @ W0 + scale * (x @ A) @ B`` as a Pallas kernel
    (differentiable — see the custom VJP above).

    Args:
      x:  [m, k] activations.
      w0: [k, n] frozen base weight.
      a:  [k, r] LoRA down-projection.
      b:  [r, n] LoRA up-projection.
      scale: python float, LoRA alpha / rank.
      block_m / block_n: preferred output tile (clipped to divisors).
      interpret: run the kernel in interpret mode (required on CPU).

    Returns:
      [m, n] array in x.dtype.
    """
    return _lora_matmul_diff(x, w0, a, b, float(scale), block_m, block_n,
                             interpret)


def vmem_bytes_estimate(m, k, n, r, block_m=128, block_n=128,
                        dtype_bytes=4):
    """Per-grid-step VMEM footprint estimate (for §Perf bookkeeping):
    x-tile + w0-tile + a + b-tile + out-tile, in bytes."""
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    tiles = bm * k + k * bn + k * r + r * bn + bm * bn
    return tiles * dtype_bytes
