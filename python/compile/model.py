"""L2 JAX model: a LoRA transformer language model whose hot projections
run through the L1 Pallas kernels (``kernels.lora_matmul``,
``kernels.softmax_xent``), so both layers lower into a single HLO module.

Parameterization follows LoRA fine-tuning (§II-A of the paper): the base
transformer weights are **frozen**; only the low-rank A/B adapters (on
the attention q/v projections and the MLP up-projection) plus the token
embedding, final norm, and LM head train (the common
``modules_to_save=[embed, lm_head]`` recipe — with a randomly-initialized
base, adapter-only training has nothing to adapt *to*, so the embedding
and head must train for the end-to-end loss curve to be meaningful; see
DESIGN.md substitutions).

Parameters are carried as two ordered tuples — ``frozen`` and
``trainable`` — because the AOT boundary (rust ⇄ PJRT) is positional.
``param_specs`` is the single source of truth for that order; it is
exported into ``artifacts/meta.toml`` and the rust ``ParamStore`` mirrors
it.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from compile.kernels.lora_matmul import lora_matmul
from compile.kernels.softmax_xent import softmax_xent


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer + LoRA hyperparameters."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 64
    lora_rank: int = 8
    lora_alpha: float = 16.0
    batch_per_shard: int = 8

    @property
    def lora_scale(self) -> float:
        return self.lora_alpha / self.lora_rank

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        frozen, trainable = param_specs(self)
        total = 0
        for _, shape in frozen + trainable:
            n = 1
            for d in shape:
                n *= d
            total += n
        return total


# Named presets for the CLI / aot driver. "tiny" is the default test
# preset; "small" the end-to-end example; "100m" approximates the paper's
# reference scale (compile-only on this 1-core CPU box).
PRESETS = {
    "tiny": ModelConfig(),
    "small": ModelConfig(
        vocab=256, d_model=256, n_layers=4, n_heads=8, d_ff=512,
        seq_len=128, lora_rank=16, batch_per_shard=8,
    ),
    "100m": ModelConfig(
        vocab=32000, d_model=768, n_layers=12, n_heads=12, d_ff=3072,
        seq_len=512, lora_rank=16, batch_per_shard=4,
    ),
}


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) lists for frozen and trainable parameters.

    The order here IS the AOT calling convention.
    """
    frozen = []
    trainable = [("tok_emb", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        frozen += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
        r = cfg.lora_rank
        trainable += [
            (p + "wq_a", (cfg.d_model, r)),
            (p + "wq_b", (r, cfg.d_model)),
            (p + "wv_a", (cfg.d_model, r)),
            (p + "wv_b", (r, cfg.d_model)),
            (p + "w1_a", (cfg.d_model, r)),
            (p + "w1_b", (r, cfg.d_ff)),
        ]
    trainable += [
        ("final_norm", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return frozen, trainable


def init_params(cfg: ModelConfig, seed: int = 0):
    """Initialize (frozen, trainable) parameter tuples.

    Base weights: scaled-normal (a stand-in for pretrained weights).
    LoRA: A ~ normal/sqrt(d), B = 0 — the standard LoRA init, so the
    adapted model starts exactly at the base model.
    """
    f_specs, t_specs = param_specs(cfg)
    key = jax.random.PRNGKey(seed)

    def make(name, shape, key):
        if name.endswith("_b"):
            return jnp.zeros(shape, jnp.float32)
        if name.endswith("norm"):
            return jnp.ones(shape, jnp.float32)
        fan_in = shape[0]
        std = 1.0 / jnp.sqrt(jnp.maximum(1.0, fan_in))
        return jax.random.normal(key, shape, jnp.float32) * std

    frozen = []
    for name, shape in f_specs:
        key, sub = jax.random.split(key)
        frozen.append(make(name, shape, sub))
    trainable = []
    for name, shape in t_specs:
        key, sub = jax.random.split(key)
        trainable.append(make(name, shape, sub))
    return tuple(frozen), tuple(trainable)


def _rmsnorm(x, g):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _attention(cfg: ModelConfig, x, wq, wk, wv, wo, q_ab, v_ab, interpret):
    """Multi-head causal self-attention with LoRA on q and v."""
    bsz, seq, d = x.shape
    x2 = x.reshape(bsz * seq, d)
    scale = cfg.lora_scale
    q = lora_matmul(x2, wq, q_ab[0], q_ab[1], scale, interpret=interpret)
    v = lora_matmul(x2, wv, v_ab[0], v_ab[1], scale, interpret=interpret)
    k = x2 @ wk
    h, hd = cfg.n_heads, cfg.head_dim
    q = q.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(bsz, seq, h, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(bsz * seq, d)
    return (out @ wo).reshape(bsz, seq, d)


def forward(cfg: ModelConfig, frozen, trainable, tokens, interpret=True):
    """Logits for ``tokens[:, :-1]``: [batch, seq, vocab]."""
    f = list(frozen)
    t = list(trainable)
    tok_emb = t[0]
    x = tok_emb[tokens[:, :-1]]  # [B, S, D]
    fi = 0
    ti = 1
    scale = cfg.lora_scale
    for _ in range(cfg.n_layers):
        attn_norm, wq, wk, wv, wo, mlp_norm, w1, w2 = f[fi : fi + 8]
        fi += 8
        wq_a, wq_b, wv_a, wv_b, w1_a, w1_b = t[ti : ti + 6]
        ti += 6
        h = _rmsnorm(x, attn_norm)
        x = x + _attention(
            cfg, h, wq, wk, wv, wo, (wq_a, wq_b), (wv_a, wv_b), interpret
        )
        h = _rmsnorm(x, mlp_norm)
        bsz, seq, d = h.shape
        h2 = h.reshape(bsz * seq, d)
        up = lora_matmul(h2, w1, w1_a, w1_b, scale, interpret=interpret)
        x = x + (jax.nn.silu(up) @ w2).reshape(bsz, seq, d)
    final_norm, lm_head = t[ti], t[ti + 1]
    x = _rmsnorm(x, final_norm)
    return x @ lm_head


def loss_fn(cfg: ModelConfig, frozen, trainable, tokens, interpret=True):
    """Next-token LM loss via the Pallas xent kernel.

    The row-block size is chosen so one (rows × vocab) logits tile stays
    within ~8 MiB of VMEM — at byte-level vocab that is the full 256-row
    default; at the 100m preset (vocab 32k) it shrinks to 64 rows.
    """
    logits = forward(cfg, frozen, trainable, tokens, interpret=interpret)
    bsz, seq, v = logits.shape
    targets = tokens[:, 1:].reshape(-1)
    block_rows = max(8, min(256, (8 << 20) // (v * 4)))
    return softmax_xent(
        logits.reshape(bsz * seq, v),
        targets,
        block_rows=block_rows,
        interpret=interpret,
    )


def grad_step(cfg: ModelConfig, frozen, trainable, tokens, interpret=True):
    """(loss, grads-on-trainable) — the per-shard artifact. The rust
    coordinator averages grads across data-parallel shards."""
    loss, grads = jax.value_and_grad(
        lambda tr: loss_fn(cfg, frozen, tr, tokens, interpret=interpret)
    )(tuple(trainable))
    return (loss,) + tuple(grads)


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01


def apply_step(opt: OptConfig, trainable, m, v, grads, step):
    """AdamW update over the trainable tuple — the second artifact.

    ``step`` is the 1-based update counter (int32 scalar).
    """
    t = step.astype(jnp.float32)
    b1, b2 = opt.beta1, opt.beta2
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)
    new_t: List[jnp.ndarray] = []
    new_m: List[jnp.ndarray] = []
    new_v: List[jnp.ndarray] = []
    for p, mi, vi, g in zip(trainable, m, v, grads):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * jnp.square(g)
        mhat = mi / bc1
        vhat = vi / bc2
        upd = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * p
        new_t.append(p - opt.lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return tuple(new_t) + tuple(new_m) + tuple(new_v)


def make_example_tokens(cfg: ModelConfig):
    """Shape/dtype example for lowering."""
    return jnp.zeros((cfg.batch_per_shard, cfg.seq_len + 1), jnp.int32)
