"""AOT driver: lower the L2/L1 stack to HLO **text** artifacts the rust
runtime loads via PJRT. Runs once at build time (`make artifacts`);
python is never on the request path.

Interchange format is HLO text, NOT serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts (per preset):
  artifacts/grad_step.hlo.txt   (frozen…, trainable…, tokens) →
                                (loss, grads…)
  artifacts/apply_step.hlo.txt  (trainable…, m…, v…, grads…, step) →
                                (trainable…, m…, v…)
  artifacts/init.hlo.txt        ()     → (frozen…, trainable…)
  artifacts/meta.toml           model config + parameter calling
                                convention (mirrored by rust ParamStore)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import (
    OptConfig,
    PRESETS,
    apply_step,
    grad_step,
    init_params,
    make_example_tokens,
    param_specs,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_struct(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_grad_step(cfg):
    f_specs, t_specs = param_specs(cfg)
    f_ex = tuple(shape_struct(s) for _, s in f_specs)
    t_ex = tuple(shape_struct(s) for _, s in t_specs)
    tok_ex = make_example_tokens(cfg)

    def fn(frozen, trainable, tokens):
        return grad_step(cfg, frozen, trainable, tokens, interpret=True)

    return jax.jit(fn).lower(f_ex, t_ex, tok_ex)


def lower_apply_step(cfg, opt):
    _, t_specs = param_specs(cfg)
    t_ex = tuple(shape_struct(s) for _, s in t_specs)
    step_ex = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(trainable, m, v, grads, step):
        return apply_step(opt, trainable, m, v, grads, step)

    return jax.jit(fn).lower(t_ex, t_ex, t_ex, t_ex, step_ex)


def lower_init(cfg, seed):
    def fn():
        frozen, trainable = init_params(cfg, seed)
        return tuple(frozen) + tuple(trainable)

    return jax.jit(fn).lower()


def toml_escape(s):
    return s.replace("\\", "\\\\").replace('"', '\\"')


def write_meta(path, preset, cfg, opt, seed):
    """Emit meta.toml — parsed by rust's config::toml, so stick to the
    supported subset (tables, scalars, homogeneous arrays)."""
    f_specs, t_specs = param_specs(cfg)
    lines = []
    lines.append("[model]")
    lines.append(f'preset = "{toml_escape(preset)}"')
    lines.append(f"vocab = {cfg.vocab}")
    lines.append(f"d_model = {cfg.d_model}")
    lines.append(f"n_layers = {cfg.n_layers}")
    lines.append(f"n_heads = {cfg.n_heads}")
    lines.append(f"d_ff = {cfg.d_ff}")
    lines.append(f"seq_len = {cfg.seq_len}")
    lines.append(f"lora_rank = {cfg.lora_rank}")
    lines.append(f"lora_alpha = {cfg.lora_alpha}")
    lines.append(f"batch_per_shard = {cfg.batch_per_shard}")
    lines.append(f"param_count = {cfg.param_count()}")
    lines.append(f"init_seed = {seed}")
    lines.append("")
    lines.append("[optim]")
    lines.append(f"lr = {opt.lr}")
    lines.append(f"beta1 = {opt.beta1}")
    lines.append(f"beta2 = {opt.beta2}")
    lines.append(f"eps = {opt.eps}")
    lines.append(f"weight_decay = {opt.weight_decay}")
    lines.append("")
    lines.append("[artifacts]")
    lines.append('grad_step = "grad_step.hlo.txt"')
    lines.append('apply_step = "apply_step.hlo.txt"')
    lines.append('init = "init.hlo.txt"')
    lines.append("")

    def emit_params(table, specs):
        lines.append(f"[{table}]")
        names = ", ".join(f'"{toml_escape(n)}"' for n, _ in specs)
        lines.append(f"names = [{names}]")
        shapes = ", ".join(
            "[" + ", ".join(str(d) for d in shape) + "]" for _, shape in specs
        )
        lines.append(f"shapes = [{shapes}]")
        lines.append("")

    emit_params("params.frozen", f_specs)
    emit_params("params.trainable", t_specs)
    with open(path, "w") as f:
        f.write("\n".join(lines))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the primary artifact; its directory "
                    "receives all artifacts")
    ap.add_argument("--preset", default=os.environ.get("SPOTFINE_PRESET", "tiny"),
                    choices=sorted(PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    opt = OptConfig(lr=args.lr)
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    print(f"[aot] preset={args.preset} params={cfg.param_count():,}")

    jobs = [
        ("grad_step.hlo.txt", lambda: lower_grad_step(cfg)),
        ("apply_step.hlo.txt", lambda: lower_apply_step(cfg, opt)),
        ("init.hlo.txt", lambda: lower_init(cfg, args.seed)),
    ]
    for fname, make in jobs:
        text = to_hlo_text(make())
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] wrote {path} ({len(text):,} chars)")

    write_meta(os.path.join(outdir, "meta.toml"), args.preset, cfg, opt,
               args.seed)
    print(f"[aot] wrote {os.path.join(outdir, 'meta.toml')}")

    # The Makefile's stamp target: the primary --out file marks success.
    with open(args.out, "w") as f:
        f.write("# spotfine artifacts stamp — see grad_step/apply_step/"
                "init .hlo.txt + meta.toml in this directory\n")


if __name__ == "__main__":
    main()
